//! GPU hardware specs, per-role device profiles, and cluster topology.

/// A single accelerator's capabilities. Defaults model the paper's testbed
/// (NVIDIA A100-80GB SXM).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense fp16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: f64,
    /// Streaming multiprocessors (the MPS partitioning unit).
    pub num_sms: u32,
    /// Inter-GPU interconnect bandwidth (NVLink), B/s.
    pub interconnect_bw: f64,
    /// Achievable fraction of peak FLOPs for large GEMMs (cuBLAS-class).
    pub compute_eff: f64,
    /// Achievable fraction of peak bandwidth for streaming kernels
    /// (calibrated to the paper's Fig 18: the attention executor reaches
    /// 83% of the bandwidth capacity limit).
    pub bw_eff: f64,
}

impl GpuSpec {
    /// NVIDIA A100-80GB SXM, the paper's testbed GPU.
    pub const fn a100_80g() -> Self {
        GpuSpec {
            name: "A100-80GB-SXM",
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
            hbm_capacity: 80e9,
            num_sms: 108,
            interconnect_bw: 600e9,
            compute_eff: 0.62,
            bw_eff: 0.83,
        }
    }

    /// NVIDIA H20: the compute-cut, memory-rich Hopper variant — the
    /// canonical "cheaper, memory-richer" attention-executor device the
    /// model-attention-disaggregation line (arXiv 2405.01814) targets:
    /// less than half the A100's dense FLOPs, but 2x the HBM bandwidth
    /// and more capacity.
    pub const fn h20_96g() -> Self {
        GpuSpec {
            name: "H20-96GB",
            peak_flops: 148e12,
            hbm_bw: 4.0e12,
            hbm_capacity: 96e9,
            num_sms: 78,
            interconnect_bw: 900e9,
            compute_eff: 0.60,
            bw_eff: 0.83,
        }
    }

    /// Preset lookup by `name` — the device vocabulary of the JSON config
    /// plane (`FleetConfig::group_profiles` carries GPUs by name).
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        [Self::a100_80g(), Self::h20_96g()].into_iter().find(|g| g.name == name)
    }
}

impl Default for GpuSpec {
    fn default() -> Self {
        Self::a100_80g()
    }
}

/// Which instance class a [`DeviceProfile`] prices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceRole {
    /// Prefill instances (prompt processing).
    Prefill,
    /// Decode instances (token generation, non-attention + local attention).
    Decode,
    /// The offloaded-attention executor. Colocated on the prefill GPU by
    /// default (the paper's deployment); a standalone profile models the
    /// memory-rich dedicated device of arXiv 2405.01814.
    Executor,
}

impl DeviceRole {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceRole::Prefill => "prefill",
            DeviceRole::Decode => "decode",
            DeviceRole::Executor => "executor",
        }
    }
}

/// One instance class's device: a GPU plus an optional SM partition it
/// runs inside (the intra-GPU disaggregation of Nexus / RAPID-Serve,
/// priced through `gpu_model/partition.rs`). `sm_frac: None` means the
/// role owns the whole GPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub gpu: GpuSpec,
    pub role: DeviceRole,
    /// SM fraction the role is confined to, in (0, 1]. `None` = whole GPU.
    pub sm_frac: Option<f64>,
}

impl DeviceProfile {
    /// A role owning the whole GPU.
    pub const fn whole(gpu: GpuSpec, role: DeviceRole) -> Self {
        DeviceProfile { gpu, role, sm_frac: None }
    }

    /// A role confined to an SM partition of the GPU.
    pub const fn partitioned(gpu: GpuSpec, role: DeviceRole, sm_frac: f64) -> Self {
        DeviceProfile { gpu, role, sm_frac: Some(sm_frac) }
    }
}

/// Per-role device overrides. Every slot is optional: `None` keeps the
/// role on [`ClusterSpec::gpu`] exactly as before the refactor, so the
/// all-`None` value (the default) is structurally inert — pinned
/// bit-identical by `rust/tests/hetero.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeviceProfiles {
    /// Prefill instances' device. A partitioned profile models the
    /// intra-GPU SM split (prefill pays `prefill_slowdown(sm_frac)`).
    pub prefill: Option<DeviceProfile>,
    /// Decode instances' device.
    pub decode: Option<DeviceProfile>,
    /// Attention executor's device. `None` = colocated on the prefill
    /// GPU at `attn_executor_sm_frac` (the paper's deployment); `Some` =
    /// a standalone executor device (no interference with prefill, KV
    /// pool sized from its own HBM).
    pub executor: Option<DeviceProfile>,
}

/// Cluster topology for a PD-disaggregated deployment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    /// Number of prefill instances (GPU groups running prefill).
    pub n_prefill: u32,
    /// Number of decoding instances.
    pub n_decode: u32,
    /// Fraction of HBM usable for model state (vLLM's
    /// `gpu_memory_utilization`; the paper uses 0.8).
    pub memory_utilization: f64,
    /// SM fraction granted to the attention executor on prefill GPUs
    /// (Adrenaline's configurable MPS knob, §3.3.2). Only meaningful
    /// while the executor is colocated (`profiles.executor` unset).
    ///
    /// Calibration: Fig 18a reports the executor sustaining 83 % of the
    /// bandwidth-capacity limit while active, which on the Fig 9 curve
    /// requires roughly half the SMs (bw_frac(0.5) ≈ 0.80); Fig 10 shows
    /// prefill tolerating that reservation. 0.5 reproduces both panels.
    pub attn_executor_sm_frac: f64,
    /// Per-role device overrides. `None` (the default) prices every
    /// instance class on `gpu` — bit-identical to the single-profile
    /// cost plane (pinned by `rust/tests/hetero.rs`).
    pub profiles: Option<DeviceProfiles>,
}

impl ClusterSpec {
    /// The paper's end-to-end configuration: one prefill + one decode
    /// instance per pair (n = 1 in Eq. 1).
    pub fn paper_default() -> Self {
        ClusterSpec {
            gpu: GpuSpec::a100_80g(),
            n_prefill: 1,
            n_decode: 1,
            memory_utilization: 0.8,
            attn_executor_sm_frac: 0.5,
            profiles: None,
        }
    }

    /// Average prefill instances per decode instance (the `n` in Eq. 1).
    pub fn prefill_per_decode(&self) -> f64 {
        self.n_prefill as f64 / self.n_decode as f64
    }

    /// Usable HBM for KV + weights on one instance, bytes.
    pub fn usable_hbm(&self) -> f64 {
        self.gpu.hbm_capacity * self.memory_utilization
    }

    /// Usable HBM on an arbitrary device under this cluster's
    /// `memory_utilization` (the per-profile variant of [`usable_hbm`]).
    ///
    /// [`usable_hbm`]: ClusterSpec::usable_hbm
    pub fn usable_hbm_of(&self, gpu: &GpuSpec) -> f64 {
        gpu.hbm_capacity * self.memory_utilization
    }

    /// The prefill instances' resolved device profile.
    pub fn prefill_profile(&self) -> DeviceProfile {
        self.profiles
            .and_then(|p| p.prefill)
            .unwrap_or(DeviceProfile { gpu: self.gpu, role: DeviceRole::Prefill, sm_frac: None })
    }

    /// The decode instances' resolved device profile.
    pub fn decode_profile(&self) -> DeviceProfile {
        self.profiles
            .and_then(|p| p.decode)
            .unwrap_or(DeviceProfile { gpu: self.gpu, role: DeviceRole::Decode, sm_frac: None })
    }

    /// The attention executor's resolved device profile. Colocated by
    /// default: the prefill device's GPU at `attn_executor_sm_frac` (the
    /// `max(1e-3)` clamp mirrors the sim's historical guard against a
    /// zero partition).
    pub fn executor_profile(&self) -> DeviceProfile {
        self.profiles.and_then(|p| p.executor).unwrap_or(DeviceProfile {
            gpu: self.prefill_profile().gpu,
            role: DeviceRole::Executor,
            sm_frac: Some(self.attn_executor_sm_frac.max(1e-3)),
        })
    }

    /// Whether the executor shares the prefill GPU (the paper's
    /// deployment). Standalone executor profiles (arXiv 2405.01814) do
    /// not slow prefill down and size their KV pool from their own HBM.
    pub fn executor_is_colocated(&self) -> bool {
        self.profiles.is_none_or(|p| p.executor.is_none())
    }
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self::paper_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_numbers() {
        let g = GpuSpec::a100_80g();
        assert_eq!(g.num_sms, 108);
        assert!(g.hbm_capacity > 79e9);
        assert!(g.peak_flops > 3e14);
    }

    #[test]
    fn h20_is_memory_rich_and_compute_cut() {
        let a = GpuSpec::a100_80g();
        let h = GpuSpec::h20_96g();
        assert!(h.peak_flops < a.peak_flops / 2.0, "the executor device is cheap on compute");
        assert!(h.hbm_bw > a.hbm_bw, "but richer on bandwidth");
        assert!(h.hbm_capacity > a.hbm_capacity, "and capacity");
    }

    #[test]
    fn preset_lookup_by_name() {
        assert_eq!(GpuSpec::by_name("A100-80GB-SXM"), Some(GpuSpec::a100_80g()));
        assert_eq!(GpuSpec::by_name("H20-96GB"), Some(GpuSpec::h20_96g()));
        assert_eq!(GpuSpec::by_name("TPUv9"), None);
    }

    #[test]
    fn usable_hbm_honors_utilization() {
        let c = ClusterSpec::paper_default();
        assert!((c.usable_hbm() - 64e9).abs() < 1e9);
        assert_eq!(
            c.usable_hbm_of(&c.gpu).to_bits(),
            c.usable_hbm().to_bits(),
            "the per-profile variant is the same expression"
        );
    }

    #[test]
    fn prefill_per_decode_ratio() {
        let mut c = ClusterSpec::paper_default();
        c.n_prefill = 3;
        c.n_decode = 2;
        assert!((c.prefill_per_decode() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn default_profiles_resolve_to_the_cluster_gpu() {
        let c = ClusterSpec::paper_default();
        assert!(c.profiles.is_none(), "per-role profiles are opt-in");
        assert_eq!(c.prefill_profile(), DeviceProfile::whole(c.gpu, DeviceRole::Prefill));
        assert_eq!(c.decode_profile(), DeviceProfile::whole(c.gpu, DeviceRole::Decode));
        let exec = c.executor_profile();
        assert_eq!(exec.gpu, c.gpu);
        assert_eq!(exec.sm_frac, Some(c.attn_executor_sm_frac));
        assert!(c.executor_is_colocated());
    }

    #[test]
    fn explicit_profiles_override_per_role() {
        let mut c = ClusterSpec::paper_default();
        c.profiles = Some(DeviceProfiles {
            prefill: Some(DeviceProfile::partitioned(c.gpu, DeviceRole::Prefill, 0.45)),
            decode: None,
            executor: Some(DeviceProfile::whole(GpuSpec::h20_96g(), DeviceRole::Executor)),
        });
        assert_eq!(c.prefill_profile().sm_frac, Some(0.45));
        assert_eq!(c.decode_profile().gpu, c.gpu, "unset slots keep the cluster GPU");
        assert_eq!(c.executor_profile().gpu, GpuSpec::h20_96g());
        assert!(!c.executor_is_colocated());
        // An explicit all-None profile set is colocated too.
        c.profiles = Some(DeviceProfiles::default());
        assert!(c.executor_is_colocated());
    }
}
