//! GPU hardware specs and cluster topology.

/// A single accelerator's capabilities. Defaults model the paper's testbed
/// (NVIDIA A100-80GB SXM).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense fp16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: f64,
    /// Streaming multiprocessors (the MPS partitioning unit).
    pub num_sms: u32,
    /// Inter-GPU interconnect bandwidth (NVLink), B/s.
    pub interconnect_bw: f64,
    /// Achievable fraction of peak FLOPs for large GEMMs (cuBLAS-class).
    pub compute_eff: f64,
    /// Achievable fraction of peak bandwidth for streaming kernels
    /// (calibrated to the paper's Fig 18: the attention executor reaches
    /// 83% of the bandwidth capacity limit).
    pub bw_eff: f64,
}

impl GpuSpec {
    /// NVIDIA A100-80GB SXM, the paper's testbed GPU.
    pub const fn a100_80g() -> Self {
        GpuSpec {
            name: "A100-80GB-SXM",
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
            hbm_capacity: 80e9,
            num_sms: 108,
            interconnect_bw: 600e9,
            compute_eff: 0.62,
            bw_eff: 0.83,
        }
    }
}

impl Default for GpuSpec {
    fn default() -> Self {
        Self::a100_80g()
    }
}

/// Cluster topology for a PD-disaggregated deployment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    /// Number of prefill instances (GPU groups running prefill).
    pub n_prefill: u32,
    /// Number of decoding instances.
    pub n_decode: u32,
    /// Fraction of HBM usable for model state (vLLM's
    /// `gpu_memory_utilization`; the paper uses 0.8).
    pub memory_utilization: f64,
    /// SM fraction granted to the attention executor on prefill GPUs
    /// (Adrenaline's configurable MPS knob, §3.3.2).
    ///
    /// Calibration: Fig 18a reports the executor sustaining 83 % of the
    /// bandwidth-capacity limit while active, which on the Fig 9 curve
    /// requires roughly half the SMs (bw_frac(0.5) ≈ 0.80); Fig 10 shows
    /// prefill tolerating that reservation. 0.5 reproduces both panels.
    pub attn_executor_sm_frac: f64,
}

impl ClusterSpec {
    /// The paper's end-to-end configuration: one prefill + one decode
    /// instance per pair (n = 1 in Eq. 1).
    pub fn paper_default() -> Self {
        ClusterSpec {
            gpu: GpuSpec::a100_80g(),
            n_prefill: 1,
            n_decode: 1,
            memory_utilization: 0.8,
            attn_executor_sm_frac: 0.5,
        }
    }

    /// Average prefill instances per decode instance (the `n` in Eq. 1).
    pub fn prefill_per_decode(&self) -> f64 {
        self.n_prefill as f64 / self.n_decode as f64
    }

    /// Usable HBM for KV + weights on one instance, bytes.
    pub fn usable_hbm(&self) -> f64 {
        self.gpu.hbm_capacity * self.memory_utilization
    }
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self::paper_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_numbers() {
        let g = GpuSpec::a100_80g();
        assert_eq!(g.num_sms, 108);
        assert!(g.hbm_capacity > 79e9);
        assert!(g.peak_flops > 3e14);
    }

    #[test]
    fn usable_hbm_honors_utilization() {
        let c = ClusterSpec::paper_default();
        assert!((c.usable_hbm() - 64e9).abs() < 1e9);
    }

    #[test]
    fn prefill_per_decode_ratio() {
        let mut c = ClusterSpec::paper_default();
        c.n_prefill = 3;
        c.n_decode = 2;
        assert!((c.prefill_per_decode() - 1.5).abs() < 1e-12);
    }
}
