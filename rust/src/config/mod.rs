//! Configuration system: model dimension tables, GPU/cluster topology, and
//! serving policy (SLOs, offloading, batching).
//!
//! Three layers of configuration compose a deployment:
//!
//! * [`ModelSpec`] — transformer dimensions (the tiny CPU-path model and the
//!   Llama-2 7B/13B tables used by the A100-scale simulator), plus derived
//!   per-kernel FLOP/byte counts that feed the [`crate::gpu_model`]
//!   roofline.
//! * [`GpuSpec`] / [`ClusterSpec`] — hardware and topology.
//! * [`ServingConfig`] — SLOs, the offload policy, batching and bucket
//!   parameters. Loadable from JSON and overridable from the CLI.

mod cluster;
mod model;
mod serving;

pub use cluster::{ClusterSpec, DeviceProfile, DeviceProfiles, DeviceRole, GpuSpec};
pub use model::{ModelSpec, DTYPE_BYTES_F16, DTYPE_BYTES_F32};
pub use serving::{
    AutoscaleConfig, BoundsFeedbackConfig, FaultConfig, FaultKind, FleetConfig, OffloadPolicy,
    OverloadConfig, RebalanceConfig, RouterPolicy, ScriptedFault, ServingConfig,
    ServingConfigBuilder, SloConfig,
};
