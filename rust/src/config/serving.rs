//! Serving policy: SLOs, offload policy, batching and bucketing parameters.

use super::cluster::{DeviceProfile, DeviceProfiles, DeviceRole, GpuSpec};

/// Latency service-level objectives (the paper's TTFT / TPOT targets).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// Time-to-first-token target, seconds.
    pub ttft_s: f64,
    /// Time-per-output-token target, seconds.
    pub tpot_s: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        // Interactive chatbot targets commonly used by PD-disaggregation
        // papers (DistServe-style): 1 s TTFT, 100 ms TPOT.
        SloConfig { ttft_s: 1.0, tpot_s: 0.1 }
    }
}

/// How the proxy decides which requests offload their decode attention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OffloadPolicy {
    /// Vanilla PD disaggregation (the vLLM baseline): never offload.
    Disabled,
    /// Offload a fixed fraction of requests round-robin — the naive
    /// strategy Fig 15 sweeps and DESIGN.md ablation 3 compares against.
    FixedRatio(f64),
    /// The paper's Algorithm 1: admit offloads while within the
    /// load-derived upper bound OB(n, B_max), conditions C1/C2.
    LoadAware,
    /// Algorithm 1 with the stricter C1 (Σ max_token based — see the
    /// scheduler's fidelity note). More conservative admissions; compared
    /// in `benches/ablation_admission.rs`.
    LoadAwareStrict,
}

impl OffloadPolicy {
    pub fn is_enabled(&self) -> bool {
        !matches!(self, OffloadPolicy::Disabled)
            && !matches!(self, OffloadPolicy::FixedRatio(r) if *r <= 0.0)
    }
}

/// Runtime offload-rebalancer knobs (§3.4.2 extended: the feedback
/// controller that migrates decode attention between local and offloaded
/// while requests run, instead of fixing the split at admission).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RebalanceConfig {
    /// Controller tick period, seconds.
    pub interval_s: f64,
    /// Half-width of the prefill-pressure hysteresis band around the
    /// setpoint (pressure = queued prompt tokens / max_prefill_tokens;
    /// setpoint 0.5): the controller enters burst mode at
    /// `0.5 + hysteresis` and leaves it at `0.5 - hysteresis`.
    pub hysteresis: f64,
    /// Cap on migrations started per tick (bounds KV-transfer churn).
    pub max_migrations_per_interval: usize,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig { interval_s: 0.25, hysteresis: 0.25, max_migrations_per_interval: 16 }
    }
}

/// Online bounds-feedback knobs (§3.4.2: the proxy tracks `B_TPOT` online
/// and refreshes `OB_comp` as load shifts, instead of freezing the
/// offline roofline seed for the whole run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundsFeedbackConfig {
    /// Standalone refresh-tick period, seconds (used when no rebalancer
    /// runs; with rebalancing on, refreshes ride the rebalance ticks).
    pub interval_s: f64,
    /// EMA weight for each new step-time / request-TPOT observation.
    pub alpha: f64,
    /// Decode-step observations required before the first refresh is
    /// applied (the offline seed holds until the EMAs have warmed up).
    /// The JSON plane carries this as f64 (like every numeric field):
    /// integers up to 2^53 round-trip exactly, `u64::MAX` survives via
    /// the saturating cast, values in between lose precision.
    pub min_observations: u64,
}

impl Default for BoundsFeedbackConfig {
    fn default() -> Self {
        BoundsFeedbackConfig { interval_s: 0.25, alpha: 0.2, min_observations: 16 }
    }
}

/// Fault kinds the injection plane can schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A prefill instance — and the attention executor colocated on it —
    /// goes down; offloaded requests resident there lose their attention
    /// KV and must recompute (`engine::recovery::RecoveryAction`).
    PrefillCrash,
    /// A decode instance goes down; its requests re-route to survivors.
    DecodeCrash,
    /// One prefill instance's executor runs slow for a window: the
    /// offloaded-attention component of decode steps touching it is
    /// multiplied by `FaultConfig::straggler_factor`.
    Straggler,
}

impl FaultKind {
    fn as_str(&self) -> &'static str {
        match self {
            FaultKind::PrefillCrash => "prefill_crash",
            FaultKind::DecodeCrash => "decode_crash",
            FaultKind::Straggler => "straggler",
        }
    }
}

/// One scripted fault: `instance` enters `kind` at `at_s` and recovers
/// `down_s` seconds later.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScriptedFault {
    pub kind: FaultKind,
    pub instance: usize,
    pub at_s: f64,
    pub down_s: f64,
    /// Restrict this fault to one fleet group (ISSUE 10). `None` applies
    /// the fault in every group (and in a bare, fleetless sim);
    /// `Some(g)` requires `ServingConfig::fleet` with `g < groups` and
    /// fires only inside group `g`'s fault plane.
    pub group: Option<u32>,
}

/// Fault-injection plane (ISSUE 6). `None` on [`ServingConfig`] is
/// structurally inert: no fault events are scheduled, no RNG is consumed,
/// and runs are bit-identical to a simulator without the plane (pinned by
/// `rust/tests/faults.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Scripted fault schedule, applied on top of any stochastic faults.
    pub script: Vec<ScriptedFault>,
    /// Mean time between stochastic prefill-instance crashes, seconds
    /// (exponential, per instance, from the dedicated fault RNG stream).
    /// `None` = no stochastic prefill crashes.
    pub prefill_mtbf_s: Option<f64>,
    /// Mean time to repair a stochastic prefill crash, seconds.
    pub prefill_mttr_s: f64,
    /// Mean time between stochastic decode-instance crashes, seconds.
    pub decode_mtbf_s: Option<f64>,
    /// Mean time to repair a stochastic decode crash, seconds.
    pub decode_mttr_s: f64,
    /// Probability that any single KV-transfer attempt (prefill→decode
    /// handoff or migration) fails transiently and must retry.
    pub transfer_fail_prob: f64,
    /// Retry attempts before a transfer gives up and the request falls
    /// back to local recompute (re-prefill through the dispatch path).
    pub transfer_max_retries: u64,
    /// Initial retry backoff, seconds; doubles per attempt.
    pub transfer_backoff_s: f64,
    /// Backoff ceiling, seconds.
    pub transfer_backoff_cap_s: f64,
    /// Slowdown multiplier a `Straggler` window applies to the
    /// offloaded-attention component of affected decode steps.
    pub straggler_factor: f64,
    /// Proxy heartbeat period, seconds: health transitions are observed
    /// on `HealthTick` boundaries, which also sample the health timeline.
    pub heartbeat_s: f64,
    /// Health-aware degraded routing (the graceful mode). `false` is the
    /// naive fail-and-recompute baseline: the proxy keeps routing new
    /// work toward crashed instances and only the crash-time recompute
    /// path saves the requests (the protocol EXPERIMENTS.md §Faults
    /// compares against).
    pub health_aware: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            script: Vec::new(),
            prefill_mtbf_s: None,
            prefill_mttr_s: 5.0,
            decode_mtbf_s: None,
            decode_mttr_s: 5.0,
            transfer_fail_prob: 0.0,
            transfer_max_retries: 3,
            transfer_backoff_s: 0.05,
            transfer_backoff_cap_s: 1.0,
            straggler_factor: 2.0,
            heartbeat_s: 0.25,
            health_aware: true,
        }
    }
}

/// Cluster-router policy for a fleet of P/D groups (ISSUE 8). Decides
/// which group's proxy a new request lands on; the per-group proxy then
/// routes within the group exactly as today.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// Cycle through groups in order (the stateless baseline).
    #[default]
    RoundRobin,
    /// Pick the group with the most offload/KV headroom — DistServe-style
    /// cluster-level goodput routing above the per-group proxies.
    LeastLoaded,
    /// Hash a session key (consecutive request-id blocks stand in for
    /// sessions in the trace plane) to a fixed group — the KV-affinity
    /// policy prefix caches would want.
    SessionSticky,
}

impl RouterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round_robin",
            RouterPolicy::LeastLoaded => "least_loaded",
            RouterPolicy::SessionSticky => "session_sticky",
        }
    }
}

/// Prefill-pool autoscaler knobs (ISSUE 8). The pool scales between
/// `min_prefill` and `max_prefill` instances on sustained queue-pressure
/// thresholds with a cooldown; scale-down drains the victim through the
/// health plane (PR 6's machinery), so `OB_mem` rescales exactly as on a
/// crash.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    /// Pool floor, instances (never drained below).
    pub min_prefill: u32,
    /// Pool ceiling, instances (clamped to the topology's `n_prefill`).
    pub max_prefill: u32,
    /// Starting pool size. `None` ⇒ start at `min_prefill`.
    pub initial_prefill: Option<u32>,
    /// Queue pressure (queued prompt tokens / `max_prefill_tokens`,
    /// averaged over active instances) above which the pool grows.
    pub scale_up_pressure: f64,
    /// Pressure below which the pool shrinks.
    pub scale_down_pressure: f64,
    /// Seconds a threshold must hold continuously before acting.
    pub sustain_s: f64,
    /// Minimum seconds between scaling actions.
    pub cooldown_s: f64,
    /// Autoscaler tick period, seconds.
    pub tick_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_prefill: 1,
            max_prefill: u32::MAX,
            initial_prefill: None,
            scale_up_pressure: 0.5,
            scale_down_pressure: 0.1,
            sustain_s: 2.0,
            cooldown_s: 5.0,
            tick_s: 0.5,
        }
    }
}

/// Router-level admission control (ISSUE 10). `None` on [`FleetConfig`]
/// is structurally inert: no admission checks, no retry queue, runs are
/// bit-identical to a fleet without the policy (pinned by
/// `rust/tests/fleet_faults.rs`). When set, an arrival is *shed* if the
/// best predicted TTFT across routable groups exceeds `ttft_budget_s` —
/// DistServe's goodput argument applied at the fleet boundary: a request
/// that cannot meet its SLO only burns capacity other requests need.
/// Rejected arrivals retry with exponential backoff up to `max_retries`
/// times before being shed for good; since predicted TTFT grows with
/// prompt length, the largest prompts shed first (graceful degradation
/// ordering).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadConfig {
    /// Shed when the best predicted TTFT across routable groups exceeds
    /// this budget, seconds.
    pub ttft_budget_s: f64,
    /// Re-admission attempts a rejected arrival gets before it is shed
    /// for good (0 = shed immediately).
    pub max_retries: u32,
    /// Initial retry backoff, seconds; doubles per attempt.
    pub retry_backoff_s: f64,
    /// Backoff ceiling, seconds.
    pub retry_backoff_cap_s: f64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            // 2.5× the default 1 s TTFT SLO: admit requests that merely
            // queue briefly, shed the hopeless tail.
            ttft_budget_s: 2.5,
            max_retries: 2,
            retry_backoff_s: 0.25,
            retry_backoff_cap_s: 2.0,
        }
    }
}

/// Fleet layer (ISSUE 8). `None` on [`ServingConfig`] is structurally
/// inert: no router, no autoscaler state, no extra events — runs are
/// bit-identical to a simulator without the layer (pinned by
/// `rust/tests/fleet.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Number of independent P/D groups behind the cluster router.
    pub groups: u32,
    /// Cluster-level routing policy.
    pub router: RouterPolicy,
    /// Per-group prefill-pool autoscaling. `None` = fixed pools.
    pub autoscale: Option<AutoscaleConfig>,
    /// Per-group device profiles (ISSUE 9): entry `g` overrides group
    /// `g`'s `ClusterSpec::profiles`, so a fleet can mix homogeneous and
    /// heterogeneous groups. `None` entries — and groups past the end of
    /// the list — keep the base cluster's devices. Empty (the default) is
    /// structurally inert.
    pub group_profiles: Vec<Option<DeviceProfiles>>,
    /// Router-level admission control (ISSUE 10). `None` = admit
    /// everything (structurally inert, bit-identical).
    pub overload: Option<OverloadConfig>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            groups: 1,
            router: RouterPolicy::RoundRobin,
            autoscale: None,
            group_profiles: Vec::new(),
            overload: None,
        }
    }
}

/// Full serving configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    pub slo: SloConfig,
    pub offload: OffloadPolicy,
    /// Max requests per decode batch (scheduler cap; HBM may bind earlier).
    pub max_batch: usize,
    /// Max prompt tokens batched into one prefill step.
    pub max_prefill_tokens: usize,
    /// KV block size in tokens (vLLM uses 16).
    pub kv_block_tokens: usize,
    /// Batch-bucket sizes captured for the decode path — the first
    /// dimension (C_d) of the paper's 2-D CUDA-graph grid. Must be a
    /// subset of the buckets in artifacts/manifest.json when running the
    /// real CPU path.
    pub decode_buckets: Vec<usize>,
    /// Bucket sizes for the offloaded-attention dimension (C_o).
    pub offload_buckets: Vec<usize>,
    /// Offline-profiled `B_max`: largest batch for which the non-attention
    /// kernels stay memory-bound (Eq. 2). `None` ⇒ derive from the GPU
    /// model at startup.
    pub b_max_override: Option<usize>,
    /// Token capacity of the attention executor's offload KV pool on the
    /// real path (`HBM_pi` in Eq 1). `None` = unbounded (the tiny model
    /// never fills host memory); tests use small budgets to exercise the
    /// admission fallback.
    pub executor_kv_capacity_tokens: Option<usize>,
    /// Token capacity of the decode instance's local KV pool (`HBM_d`).
    pub decode_kv_capacity_tokens: Option<usize>,
    /// Charge simulator step costs at exact batch sizes instead of padding
    /// to the captured executable-bucket pair (§3.2.2). The bucketed model
    /// is the default (it is what the real 2-D grid executes); the exact
    /// path is kept for ablations and bit-identical regression against the
    /// pre-bucketing baselines. Env `ADRENALINE_EXACT_COSTS=1` forces it
    /// regardless of this field.
    pub exact_costs: bool,
    /// Disable steady-state decode leaping in the simulator and schedule
    /// every decode step as its own event (the per-step reference path).
    /// Leaping is the default and is bit-identical to the reference on
    /// every reported quantity except `events_processed` (pinned by
    /// `rust/tests/step_leap.rs`); the switch exists for ablation,
    /// regression bisection, and the paired perf rows in BENCH_sim.json.
    /// Env `ADRENALINE_NO_LEAP=1` forces it regardless of this field.
    pub no_leap: bool,
    /// Disable within-run parallelism: the epoch engine still runs (so the
    /// leap-mode execution order is unchanged) but prices every instance's
    /// step series inline on the simulation thread instead of on the
    /// worker pool. The parallel path is bit-identical to this serial
    /// reference on every reported quantity (pinned by
    /// `rust/tests/par_run.rs`); the switch exists for debugging,
    /// regression bisection, and the paired perf rows in BENCH_sim.json.
    /// Env `ADRENALINE_NO_PAR=1` forces it regardless of this field.
    pub no_par: bool,
    /// Requested pricing concurrency for the within-run epoch pool,
    /// *including* the simulation thread (the pool spawns `par_workers−1`
    /// persistent workers, subject to the process-wide thread budget).
    /// `0` (the default) sizes automatically from the decode-instance
    /// count; `1` is equivalent to `no_par`. Exists for the BENCH_par
    /// scaling sweep — bit-identity holds at every worker count, so this
    /// knob has no effect on reported results, only on wall-clock.
    pub par_workers: usize,
    /// Runtime offload rebalancing. `None` (the default) keeps the
    /// one-shot admission-time split — bit-identical to the
    /// pre-rebalancer simulator (pinned by `rust/tests/rebalance.rs`).
    pub rebalance: Option<RebalanceConfig>,
    /// Online B_TPOT bounds feedback. `None` (the default) keeps the
    /// offline roofline seed frozen for the whole run — no observation
    /// hooks fire and no refresh ticks are scheduled (pinned by
    /// `rust/tests/bounds_feedback.rs`).
    pub bounds_feedback: Option<BoundsFeedbackConfig>,
    /// Fault injection. `None` (the default) schedules no fault events,
    /// consumes no RNG, and leaves every run bit-identical to a simulator
    /// without the plane (pinned by `rust/tests/faults.rs`).
    pub fault: Option<FaultConfig>,
    /// Fleet layer: cluster router over N P/D groups plus prefill-pool
    /// autoscaling. `None` (the default) is structurally inert — no
    /// router, no scaler, bit-identical to the single-group simulator
    /// (pinned by `rust/tests/fleet.rs`).
    pub fleet: Option<FleetConfig>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            slo: SloConfig::default(),
            offload: OffloadPolicy::LoadAware,
            max_batch: 256,
            max_prefill_tokens: 8192,
            kv_block_tokens: 16,
            decode_buckets: vec![1, 2, 4, 8],
            offload_buckets: vec![1, 2, 4, 8],
            b_max_override: None,
            executor_kv_capacity_tokens: None,
            decode_kv_capacity_tokens: None,
            exact_costs: false,
            no_leap: false,
            no_par: false,
            par_workers: 0,
            rebalance: None,
            bounds_feedback: None,
            fault: None,
            fleet: None,
        }
    }
}

impl ServingConfig {
    /// Baseline (vLLM-style PD disaggregation, no offloading).
    pub fn baseline() -> Self {
        ServingConfig { offload: OffloadPolicy::Disabled, ..Default::default() }
    }

    /// Load from a JSON file (hand-rolled parser; see `util::json`).
    pub fn from_json_file(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> crate::Result<Self> {
        use crate::util::json::Json;
        let v = Json::parse(text)?;
        let mut cfg = ServingConfig::default();
        if let Some(slo) = v.get("slo") {
            if let Some(t) = slo.get("ttft_s").and_then(Json::as_f64) {
                cfg.slo.ttft_s = t;
            }
            if let Some(t) = slo.get("tpot_s").and_then(Json::as_f64) {
                cfg.slo.tpot_s = t;
            }
        }
        if let Some(off) = v.get("offload") {
            cfg.offload = match off {
                Json::Str(s) if s == "disabled" => OffloadPolicy::Disabled,
                Json::Str(s) if s == "load_aware" => OffloadPolicy::LoadAware,
                Json::Str(s) if s == "load_aware_strict" => OffloadPolicy::LoadAwareStrict,
                Json::Num(r) => OffloadPolicy::FixedRatio(*r),
                other => anyhow::bail!("bad offload policy: {other}"),
            };
        }
        let usize_field = |key: &str, out: &mut usize| {
            if let Some(n) = v.get(key).and_then(Json::as_u64) {
                *out = n as usize;
            }
        };
        usize_field("max_batch", &mut cfg.max_batch);
        usize_field("max_prefill_tokens", &mut cfg.max_prefill_tokens);
        usize_field("kv_block_tokens", &mut cfg.kv_block_tokens);
        let bucket_field = |key: &str, out: &mut Vec<usize>| -> crate::Result<()> {
            if let Some(arr) = v.get(key).and_then(Json::as_arr) {
                *out = arr
                    .iter()
                    .map(|b| {
                        b.as_u64()
                            .map(|n| n as usize)
                            .ok_or_else(|| anyhow::anyhow!("bad bucket in {key}"))
                    })
                    .collect::<crate::Result<_>>()?;
            }
            Ok(())
        };
        bucket_field("decode_buckets", &mut cfg.decode_buckets)?;
        bucket_field("offload_buckets", &mut cfg.offload_buckets)?;
        // Validate the executable-bucket grid here, where a bad config
        // file surfaces as a proper `Err`, instead of letting it reach
        // `GraphCache::new`'s panic mid-setup.
        crate::coordinator::GraphCache::try_new(&cfg.decode_buckets, &cfg.offload_buckets, None)
            .map(|_| ())?;
        if let Some(n) = v.get("b_max").and_then(Json::as_u64) {
            cfg.b_max_override = Some(n as usize);
        }
        if let Some(n) = v.get("executor_kv_tokens").and_then(Json::as_u64) {
            cfg.executor_kv_capacity_tokens = Some(n as usize);
        }
        if let Some(n) = v.get("decode_kv_tokens").and_then(Json::as_u64) {
            cfg.decode_kv_capacity_tokens = Some(n as usize);
        }
        if let Some(b) = v.get("exact_costs").and_then(Json::as_bool) {
            cfg.exact_costs = b;
        }
        if let Some(b) = v.get("no_leap").and_then(Json::as_bool) {
            cfg.no_leap = b;
        }
        if let Some(b) = v.get("no_par").and_then(Json::as_bool) {
            cfg.no_par = b;
        }
        if let Some(n) = v.get("par_workers").and_then(Json::as_u64) {
            cfg.par_workers = n as usize;
        }
        // Only an *object* enables the controller: `"rebalance": null`
        // (the natural spelling of "off") stays off, and anything else is
        // a config error rather than silently-enabled defaults.
        match v.get("rebalance") {
            None | Some(Json::Null) => {}
            Some(rb @ Json::Obj(_)) => {
                let mut r = RebalanceConfig::default();
                // A present-but-wrong-typed field is a config error, not a
                // silent default (same discipline as `bounds_feedback`).
                if let Some(x) = rb.get("interval_s") {
                    r.interval_s = x
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("bad rebalance interval_s: {x}"))?;
                }
                if let Some(x) = rb.get("hysteresis") {
                    r.hysteresis = x
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("bad rebalance hysteresis: {x}"))?;
                }
                if let Some(x) = rb.get("max_migrations") {
                    r.max_migrations_per_interval = x
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("bad rebalance max_migrations: {x}"))?
                        as usize;
                }
                anyhow::ensure!(
                    r.interval_s.is_finite() && r.interval_s > 0.0,
                    "rebalance interval_s must be positive and finite"
                );
                anyhow::ensure!(r.hysteresis >= 0.0, "rebalance hysteresis must be >= 0");
                cfg.rebalance = Some(r);
            }
            Some(other) => anyhow::bail!("bad rebalance config: {other}"),
        }
        // Same object-or-null discipline as `rebalance`.
        match v.get("bounds_feedback") {
            None | Some(Json::Null) => {}
            Some(fb @ Json::Obj(_)) => {
                let mut f = BoundsFeedbackConfig::default();
                // A present-but-wrong-typed field is a config error, not a
                // silent default.
                if let Some(x) = fb.get("interval_s") {
                    f.interval_s = x
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("bad bounds_feedback interval_s: {x}"))?;
                }
                if let Some(x) = fb.get("alpha") {
                    f.alpha = x
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("bad bounds_feedback alpha: {x}"))?;
                }
                if let Some(x) = fb.get("min_observations") {
                    f.min_observations = x.as_u64().ok_or_else(|| {
                        anyhow::anyhow!("bad bounds_feedback min_observations: {x}")
                    })?;
                }
                anyhow::ensure!(
                    f.interval_s.is_finite() && f.interval_s > 0.0,
                    "bounds_feedback interval_s must be positive and finite"
                );
                anyhow::ensure!(
                    f.alpha > 0.0 && f.alpha <= 1.0,
                    "bounds_feedback alpha must be in (0, 1]"
                );
                cfg.bounds_feedback = Some(f);
            }
            Some(other) => anyhow::bail!("bad bounds_feedback config: {other}"),
        }
        // Same object-or-null discipline for the fault plane.
        match v.get("fault") {
            None | Some(Json::Null) => {}
            Some(ft @ Json::Obj(_)) => {
                let mut f = FaultConfig::default();
                if let Some(arr) = ft.get("script") {
                    let arr = arr
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("bad fault script: {arr}"))?;
                    f.script = arr
                        .iter()
                        .map(|e| -> crate::Result<ScriptedFault> {
                            let kind = match e.get("kind").and_then(Json::as_str) {
                                Some("prefill_crash") => FaultKind::PrefillCrash,
                                Some("decode_crash") => FaultKind::DecodeCrash,
                                Some("straggler") => FaultKind::Straggler,
                                _ => anyhow::bail!("bad fault kind in script entry: {e}"),
                            };
                            let instance = e
                                .get("instance")
                                .and_then(Json::as_u64)
                                .ok_or_else(|| anyhow::anyhow!("bad fault instance: {e}"))?
                                as usize;
                            let at_s = e
                                .get("at_s")
                                .and_then(Json::as_f64)
                                .ok_or_else(|| anyhow::anyhow!("bad fault at_s: {e}"))?;
                            let down_s = e
                                .get("down_s")
                                .and_then(Json::as_f64)
                                .ok_or_else(|| anyhow::anyhow!("bad fault down_s: {e}"))?;
                            anyhow::ensure!(
                                at_s.is_finite() && at_s >= 0.0,
                                "fault at_s must be finite and >= 0"
                            );
                            anyhow::ensure!(
                                down_s.is_finite() && down_s > 0.0,
                                "fault down_s must be positive and finite"
                            );
                            // Group scoping spells "everywhere" as null
                            // (or absence), like the plane toggles.
                            let group = match e.get("group") {
                                None | Some(Json::Null) => None,
                                Some(x) => Some(
                                    x.as_u64()
                                        .ok_or_else(|| {
                                            anyhow::anyhow!("bad fault group: {e}")
                                        })?
                                        as u32,
                                ),
                            };
                            Ok(ScriptedFault { kind, instance, at_s, down_s, group })
                        })
                        .collect::<crate::Result<_>>()?;
                }
                let f64_field = |key: &str, out: &mut f64| -> crate::Result<()> {
                    if let Some(x) = ft.get(key) {
                        *out = x
                            .as_f64()
                            .ok_or_else(|| anyhow::anyhow!("bad fault {key}: {x}"))?;
                    }
                    Ok(())
                };
                // MTBFs spell "off" as null (or absence), like the
                // top-level plane toggles.
                let mtbf_field = |key: &str, out: &mut Option<f64>| -> crate::Result<()> {
                    match ft.get(key) {
                        None | Some(Json::Null) => {}
                        Some(x) => {
                            let m = x
                                .as_f64()
                                .ok_or_else(|| anyhow::anyhow!("bad fault {key}: {x}"))?;
                            anyhow::ensure!(
                                m.is_finite() && m > 0.0,
                                "fault {key} must be positive and finite"
                            );
                            *out = Some(m);
                        }
                    }
                    Ok(())
                };
                mtbf_field("prefill_mtbf_s", &mut f.prefill_mtbf_s)?;
                f64_field("prefill_mttr_s", &mut f.prefill_mttr_s)?;
                mtbf_field("decode_mtbf_s", &mut f.decode_mtbf_s)?;
                f64_field("decode_mttr_s", &mut f.decode_mttr_s)?;
                f64_field("transfer_fail_prob", &mut f.transfer_fail_prob)?;
                if let Some(x) = ft.get("transfer_max_retries") {
                    f.transfer_max_retries = x.as_u64().ok_or_else(|| {
                        anyhow::anyhow!("bad fault transfer_max_retries: {x}")
                    })?;
                }
                f64_field("transfer_backoff_s", &mut f.transfer_backoff_s)?;
                f64_field("transfer_backoff_cap_s", &mut f.transfer_backoff_cap_s)?;
                f64_field("straggler_factor", &mut f.straggler_factor)?;
                f64_field("heartbeat_s", &mut f.heartbeat_s)?;
                if let Some(x) = ft.get("health_aware") {
                    f.health_aware = x
                        .as_bool()
                        .ok_or_else(|| anyhow::anyhow!("bad fault health_aware: {x}"))?;
                }
                anyhow::ensure!(
                    f.prefill_mttr_s.is_finite() && f.prefill_mttr_s > 0.0,
                    "fault prefill_mttr_s must be positive and finite"
                );
                anyhow::ensure!(
                    f.decode_mttr_s.is_finite() && f.decode_mttr_s > 0.0,
                    "fault decode_mttr_s must be positive and finite"
                );
                anyhow::ensure!(
                    (0.0..=1.0).contains(&f.transfer_fail_prob),
                    "fault transfer_fail_prob must be in [0, 1]"
                );
                anyhow::ensure!(
                    f.transfer_backoff_s.is_finite() && f.transfer_backoff_s > 0.0,
                    "fault transfer_backoff_s must be positive and finite"
                );
                anyhow::ensure!(
                    f.transfer_backoff_cap_s.is_finite()
                        && f.transfer_backoff_cap_s >= f.transfer_backoff_s,
                    "fault transfer_backoff_cap_s must be finite and >= transfer_backoff_s"
                );
                anyhow::ensure!(
                    f.straggler_factor.is_finite() && f.straggler_factor >= 1.0,
                    "fault straggler_factor must be finite and >= 1"
                );
                anyhow::ensure!(
                    f.heartbeat_s.is_finite() && f.heartbeat_s > 0.0,
                    "fault heartbeat_s must be positive and finite"
                );
                cfg.fault = Some(f);
            }
            Some(other) => anyhow::bail!("bad fault config: {other}"),
        }
        // Same object-or-null discipline for the fleet layer.
        match v.get("fleet") {
            None | Some(Json::Null) => {}
            Some(fl @ Json::Obj(_)) => {
                let mut f = FleetConfig::default();
                if let Some(x) = fl.get("groups") {
                    f.groups = x
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("bad fleet groups: {x}"))?
                        as u32;
                }
                if let Some(x) = fl.get("router") {
                    f.router = match x.as_str() {
                        Some("round_robin") => RouterPolicy::RoundRobin,
                        Some("least_loaded") => RouterPolicy::LeastLoaded,
                        Some("session_sticky") => RouterPolicy::SessionSticky,
                        _ => anyhow::bail!("bad fleet router policy: {x}"),
                    };
                }
                match fl.get("autoscale") {
                    None | Some(Json::Null) => {}
                    Some(a @ Json::Obj(_)) => {
                        let mut s = AutoscaleConfig::default();
                        let u32_field = |key: &str, out: &mut u32| -> crate::Result<()> {
                            if let Some(x) = a.get(key) {
                                *out = x
                                    .as_u64()
                                    .ok_or_else(|| anyhow::anyhow!("bad autoscale {key}: {x}"))?
                                    as u32;
                            }
                            Ok(())
                        };
                        let f64_field = |key: &str, out: &mut f64| -> crate::Result<()> {
                            if let Some(x) = a.get(key) {
                                *out = x
                                    .as_f64()
                                    .ok_or_else(|| anyhow::anyhow!("bad autoscale {key}: {x}"))?;
                            }
                            Ok(())
                        };
                        u32_field("min_prefill", &mut s.min_prefill)?;
                        u32_field("max_prefill", &mut s.max_prefill)?;
                        if let Some(x) = a.get("initial_prefill") {
                            match x {
                                Json::Null => {}
                                _ => {
                                    s.initial_prefill = Some(x.as_u64().ok_or_else(|| {
                                        anyhow::anyhow!("bad autoscale initial_prefill: {x}")
                                    })?
                                        as u32)
                                }
                            }
                        }
                        f64_field("scale_up_pressure", &mut s.scale_up_pressure)?;
                        f64_field("scale_down_pressure", &mut s.scale_down_pressure)?;
                        f64_field("sustain_s", &mut s.sustain_s)?;
                        f64_field("cooldown_s", &mut s.cooldown_s)?;
                        f64_field("tick_s", &mut s.tick_s)?;
                        anyhow::ensure!(
                            s.min_prefill >= 1,
                            "autoscale min_prefill must be >= 1"
                        );
                        anyhow::ensure!(
                            s.max_prefill >= s.min_prefill,
                            "autoscale max_prefill must be >= min_prefill"
                        );
                        anyhow::ensure!(
                            s.tick_s.is_finite() && s.tick_s > 0.0,
                            "autoscale tick_s must be positive and finite"
                        );
                        anyhow::ensure!(
                            s.sustain_s.is_finite() && s.sustain_s >= 0.0,
                            "autoscale sustain_s must be finite and >= 0"
                        );
                        anyhow::ensure!(
                            s.cooldown_s.is_finite() && s.cooldown_s >= 0.0,
                            "autoscale cooldown_s must be finite and >= 0"
                        );
                        f.autoscale = Some(s);
                    }
                    Some(other) => anyhow::bail!("bad fleet autoscale config: {other}"),
                }
                // Per-group device profiles: an array of group entries,
                // each `null` (base devices) or an object with optional
                // `prefill` / `decode` / `executor` slots, each `null` or
                // `{"gpu": "<preset name>", "sm_frac": <num>|null}`.
                match fl.get("group_profiles") {
                    None | Some(Json::Null) => {}
                    Some(Json::Arr(entries)) => {
                        let mut gp = Vec::with_capacity(entries.len());
                        for e in entries {
                            match e {
                                Json::Null => gp.push(None),
                                Json::Obj(_) => {
                                    let mut p = DeviceProfiles::default();
                                    for (slot, role) in [
                                        ("prefill", DeviceRole::Prefill),
                                        ("decode", DeviceRole::Decode),
                                        ("executor", DeviceRole::Executor),
                                    ] {
                                        match e.get(slot) {
                                            None | Some(Json::Null) => {}
                                            Some(d @ Json::Obj(_)) => {
                                                let name = d
                                                    .get("gpu")
                                                    .and_then(Json::as_str)
                                                    .ok_or_else(|| {
                                                        anyhow::anyhow!(
                                                            "device profile {slot} needs a gpu name"
                                                        )
                                                    })?;
                                                let gpu =
                                                    GpuSpec::by_name(name).ok_or_else(|| {
                                                        anyhow::anyhow!(
                                                            "unknown gpu preset: {name}"
                                                        )
                                                    })?;
                                                let sm_frac = match d.get("sm_frac") {
                                                    None | Some(Json::Null) => None,
                                                    Some(s) => {
                                                        Some(s.as_f64().ok_or_else(|| {
                                                            anyhow::anyhow!(
                                                                "bad {slot} sm_frac: {s}"
                                                            )
                                                        })?)
                                                    }
                                                };
                                                if let Some(sf) = sm_frac {
                                                    anyhow::ensure!(
                                                        sf.is_finite() && sf > 0.0 && sf <= 1.0,
                                                        "{slot} sm_frac must be in (0, 1], \
                                                         got {sf}"
                                                    );
                                                }
                                                let dp = DeviceProfile { gpu, role, sm_frac };
                                                match role {
                                                    DeviceRole::Prefill => p.prefill = Some(dp),
                                                    DeviceRole::Decode => p.decode = Some(dp),
                                                    DeviceRole::Executor => {
                                                        p.executor = Some(dp)
                                                    }
                                                }
                                            }
                                            Some(other) => anyhow::bail!(
                                                "bad {slot} device profile: {other}"
                                            ),
                                        }
                                    }
                                    gp.push(Some(p));
                                }
                                other => anyhow::bail!("bad group_profiles entry: {other}"),
                            }
                        }
                        f.group_profiles = gp;
                    }
                    Some(other) => anyhow::bail!("bad fleet group_profiles: {other}"),
                }
                // Same object-or-null discipline for admission control.
                match fl.get("overload") {
                    None | Some(Json::Null) => {}
                    Some(ov @ Json::Obj(_)) => {
                        let mut s = OverloadConfig::default();
                        let f64_field = |key: &str, out: &mut f64| -> crate::Result<()> {
                            if let Some(x) = ov.get(key) {
                                *out = x
                                    .as_f64()
                                    .ok_or_else(|| anyhow::anyhow!("bad overload {key}: {x}"))?;
                            }
                            Ok(())
                        };
                        f64_field("ttft_budget_s", &mut s.ttft_budget_s)?;
                        if let Some(x) = ov.get("max_retries") {
                            s.max_retries = x
                                .as_u64()
                                .ok_or_else(|| anyhow::anyhow!("bad overload max_retries: {x}"))?
                                as u32;
                        }
                        f64_field("retry_backoff_s", &mut s.retry_backoff_s)?;
                        f64_field("retry_backoff_cap_s", &mut s.retry_backoff_cap_s)?;
                        anyhow::ensure!(
                            s.ttft_budget_s.is_finite() && s.ttft_budget_s > 0.0,
                            "overload ttft_budget_s must be positive and finite"
                        );
                        anyhow::ensure!(
                            s.retry_backoff_s.is_finite() && s.retry_backoff_s > 0.0,
                            "overload retry_backoff_s must be positive and finite"
                        );
                        anyhow::ensure!(
                            s.retry_backoff_cap_s.is_finite()
                                && s.retry_backoff_cap_s >= s.retry_backoff_s,
                            "overload retry_backoff_cap_s must be finite and >= retry_backoff_s"
                        );
                        f.overload = Some(s);
                    }
                    Some(other) => anyhow::bail!("bad fleet overload config: {other}"),
                }
                anyhow::ensure!(f.groups >= 1, "fleet groups must be >= 1");
                anyhow::ensure!(
                    f.group_profiles.len() <= f.groups as usize,
                    "fleet group_profiles lists {} entries for {} groups",
                    f.group_profiles.len(),
                    f.groups
                );
                cfg.fleet = Some(f);
            }
            Some(other) => anyhow::bail!("bad fleet config: {other}"),
        }
        // Group-scoped scripted faults only make sense inside a fleet.
        if let Some(ft) = &cfg.fault {
            for sf in &ft.script {
                if let Some(g) = sf.group {
                    let groups = cfg.fleet.as_ref().map_or(0, |f| f.groups);
                    anyhow::ensure!(
                        g < groups,
                        "scripted fault targets group {g} but the config has {groups} \
                         fleet group(s)"
                    );
                }
            }
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut o = BTreeMap::new();
        let mut slo = BTreeMap::new();
        slo.insert("ttft_s".into(), Json::Num(self.slo.ttft_s));
        slo.insert("tpot_s".into(), Json::Num(self.slo.tpot_s));
        o.insert("slo".into(), Json::Obj(slo));
        o.insert(
            "offload".into(),
            match self.offload {
                OffloadPolicy::Disabled => Json::Str("disabled".into()),
                OffloadPolicy::LoadAware => Json::Str("load_aware".into()),
                OffloadPolicy::LoadAwareStrict => Json::Str("load_aware_strict".into()),
                OffloadPolicy::FixedRatio(r) => Json::Num(r),
            },
        );
        o.insert("max_batch".into(), Json::Num(self.max_batch as f64));
        o.insert("max_prefill_tokens".into(), Json::Num(self.max_prefill_tokens as f64));
        o.insert("kv_block_tokens".into(), Json::Num(self.kv_block_tokens as f64));
        o.insert(
            "decode_buckets".into(),
            Json::Arr(self.decode_buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        o.insert(
            "offload_buckets".into(),
            Json::Arr(self.offload_buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        if let Some(b) = self.b_max_override {
            o.insert("b_max".into(), Json::Num(b as f64));
        }
        if let Some(n) = self.executor_kv_capacity_tokens {
            o.insert("executor_kv_tokens".into(), Json::Num(n as f64));
        }
        if let Some(n) = self.decode_kv_capacity_tokens {
            o.insert("decode_kv_tokens".into(), Json::Num(n as f64));
        }
        o.insert("exact_costs".into(), Json::Bool(self.exact_costs));
        o.insert("no_leap".into(), Json::Bool(self.no_leap));
        o.insert("no_par".into(), Json::Bool(self.no_par));
        o.insert("par_workers".into(), Json::Num(self.par_workers as f64));
        if let Some(r) = self.rebalance {
            let mut rb = BTreeMap::new();
            rb.insert("interval_s".into(), Json::Num(r.interval_s));
            rb.insert("hysteresis".into(), Json::Num(r.hysteresis));
            rb.insert(
                "max_migrations".into(),
                Json::Num(r.max_migrations_per_interval as f64),
            );
            o.insert("rebalance".into(), Json::Obj(rb));
        }
        if let Some(f) = self.bounds_feedback {
            let mut fb = BTreeMap::new();
            fb.insert("interval_s".into(), Json::Num(f.interval_s));
            fb.insert("alpha".into(), Json::Num(f.alpha));
            fb.insert("min_observations".into(), Json::Num(f.min_observations as f64));
            o.insert("bounds_feedback".into(), Json::Obj(fb));
        }
        if let Some(f) = &self.fault {
            let mut ft = BTreeMap::new();
            if !f.script.is_empty() {
                ft.insert(
                    "script".into(),
                    Json::Arr(
                        f.script
                            .iter()
                            .map(|s| {
                                let mut e = BTreeMap::new();
                                e.insert("kind".into(), Json::Str(s.kind.as_str().into()));
                                e.insert("instance".into(), Json::Num(s.instance as f64));
                                e.insert("at_s".into(), Json::Num(s.at_s));
                                e.insert("down_s".into(), Json::Num(s.down_s));
                                if let Some(g) = s.group {
                                    e.insert("group".into(), Json::Num(g as f64));
                                }
                                Json::Obj(e)
                            })
                            .collect(),
                    ),
                );
            }
            if let Some(m) = f.prefill_mtbf_s {
                ft.insert("prefill_mtbf_s".into(), Json::Num(m));
            }
            ft.insert("prefill_mttr_s".into(), Json::Num(f.prefill_mttr_s));
            if let Some(m) = f.decode_mtbf_s {
                ft.insert("decode_mtbf_s".into(), Json::Num(m));
            }
            ft.insert("decode_mttr_s".into(), Json::Num(f.decode_mttr_s));
            ft.insert("transfer_fail_prob".into(), Json::Num(f.transfer_fail_prob));
            ft.insert(
                "transfer_max_retries".into(),
                Json::Num(f.transfer_max_retries as f64),
            );
            ft.insert("transfer_backoff_s".into(), Json::Num(f.transfer_backoff_s));
            ft.insert("transfer_backoff_cap_s".into(), Json::Num(f.transfer_backoff_cap_s));
            ft.insert("straggler_factor".into(), Json::Num(f.straggler_factor));
            ft.insert("heartbeat_s".into(), Json::Num(f.heartbeat_s));
            ft.insert("health_aware".into(), Json::Bool(f.health_aware));
            o.insert("fault".into(), Json::Obj(ft));
        }
        if let Some(f) = &self.fleet {
            let mut fl = BTreeMap::new();
            fl.insert("groups".into(), Json::Num(f.groups as f64));
            fl.insert("router".into(), Json::Str(f.router.name().into()));
            if let Some(s) = f.autoscale {
                let mut a = BTreeMap::new();
                a.insert("min_prefill".into(), Json::Num(s.min_prefill as f64));
                a.insert("max_prefill".into(), Json::Num(s.max_prefill as f64));
                if let Some(n) = s.initial_prefill {
                    a.insert("initial_prefill".into(), Json::Num(n as f64));
                }
                a.insert("scale_up_pressure".into(), Json::Num(s.scale_up_pressure));
                a.insert("scale_down_pressure".into(), Json::Num(s.scale_down_pressure));
                a.insert("sustain_s".into(), Json::Num(s.sustain_s));
                a.insert("cooldown_s".into(), Json::Num(s.cooldown_s));
                a.insert("tick_s".into(), Json::Num(s.tick_s));
                fl.insert("autoscale".into(), Json::Obj(a));
            }
            if !f.group_profiles.is_empty() {
                let dev = |dp: &DeviceProfile| {
                    let mut d = BTreeMap::new();
                    d.insert("gpu".into(), Json::Str(dp.gpu.name.into()));
                    d.insert(
                        "sm_frac".into(),
                        dp.sm_frac.map_or(Json::Null, Json::Num),
                    );
                    Json::Obj(d)
                };
                let entries = f
                    .group_profiles
                    .iter()
                    .map(|gp| match gp {
                        None => Json::Null,
                        Some(p) => {
                            let mut g = BTreeMap::new();
                            for (key, slot) in [
                                ("prefill", p.prefill),
                                ("decode", p.decode),
                                ("executor", p.executor),
                            ] {
                                if let Some(dp) = slot {
                                    g.insert(key.into(), dev(&dp));
                                }
                            }
                            Json::Obj(g)
                        }
                    })
                    .collect();
                fl.insert("group_profiles".into(), Json::Arr(entries));
            }
            if let Some(s) = f.overload {
                let mut ov = BTreeMap::new();
                ov.insert("ttft_budget_s".into(), Json::Num(s.ttft_budget_s));
                ov.insert("max_retries".into(), Json::Num(s.max_retries as f64));
                ov.insert("retry_backoff_s".into(), Json::Num(s.retry_backoff_s));
                ov.insert("retry_backoff_cap_s".into(), Json::Num(s.retry_backoff_cap_s));
                fl.insert("overload".into(), Json::Obj(ov));
            }
            o.insert("fleet".into(), Json::Obj(fl));
        }
        Json::Obj(o).to_string()
    }

    /// Start a typed, validating [`ServingConfigBuilder`] — the
    /// intended alternative to hand-mutating pub fields in tests and
    /// examples. Builder defaults equal [`ServingConfig::default`].
    pub fn builder() -> ServingConfigBuilder {
        ServingConfigBuilder { cfg: ServingConfig::default() }
    }
}

/// Typed builder for [`ServingConfig`] (ISSUE 8). Setters stage values;
/// [`ServingConfigBuilder::build`] validates the combination (knob
/// conflicts, bucket grids, fleet shape) and returns a proper `Err`
/// instead of letting a bad config panic mid-setup.
#[derive(Debug, Clone)]
pub struct ServingConfigBuilder {
    cfg: ServingConfig,
}

impl ServingConfigBuilder {
    pub fn slo(mut self, slo: SloConfig) -> Self {
        self.cfg.slo = slo;
        self
    }

    pub fn offload(mut self, policy: OffloadPolicy) -> Self {
        self.cfg.offload = policy;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    pub fn max_prefill_tokens(mut self, n: usize) -> Self {
        self.cfg.max_prefill_tokens = n;
        self
    }

    pub fn kv_block_tokens(mut self, n: usize) -> Self {
        self.cfg.kv_block_tokens = n;
        self
    }

    pub fn decode_buckets(mut self, buckets: Vec<usize>) -> Self {
        self.cfg.decode_buckets = buckets;
        self
    }

    pub fn offload_buckets(mut self, buckets: Vec<usize>) -> Self {
        self.cfg.offload_buckets = buckets;
        self
    }

    pub fn b_max_override(mut self, b: usize) -> Self {
        self.cfg.b_max_override = Some(b);
        self
    }

    pub fn executor_kv_capacity_tokens(mut self, n: usize) -> Self {
        self.cfg.executor_kv_capacity_tokens = Some(n);
        self
    }

    pub fn decode_kv_capacity_tokens(mut self, n: usize) -> Self {
        self.cfg.decode_kv_capacity_tokens = Some(n);
        self
    }

    pub fn exact_costs(mut self, on: bool) -> Self {
        self.cfg.exact_costs = on;
        self
    }

    pub fn no_leap(mut self, on: bool) -> Self {
        self.cfg.no_leap = on;
        self
    }

    pub fn no_par(mut self, on: bool) -> Self {
        self.cfg.no_par = on;
        self
    }

    pub fn par_workers(mut self, n: usize) -> Self {
        self.cfg.par_workers = n;
        self
    }

    pub fn rebalance(mut self, r: RebalanceConfig) -> Self {
        self.cfg.rebalance = Some(r);
        self
    }

    pub fn bounds_feedback(mut self, f: BoundsFeedbackConfig) -> Self {
        self.cfg.bounds_feedback = Some(f);
        self
    }

    pub fn fault(mut self, f: FaultConfig) -> Self {
        self.cfg.fault = Some(f);
        self
    }

    pub fn fleet(mut self, f: FleetConfig) -> Self {
        self.cfg.fleet = Some(f);
        self
    }

    /// Validate the staged combination and produce the config.
    pub fn build(self) -> crate::Result<ServingConfig> {
        let cfg = self.cfg;
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(cfg.max_prefill_tokens >= 1, "max_prefill_tokens must be >= 1");
        anyhow::ensure!(cfg.kv_block_tokens >= 1, "kv_block_tokens must be >= 1");
        anyhow::ensure!(
            !(cfg.no_par && cfg.par_workers > 1),
            "par_workers > 1 contradicts no_par (pick one)"
        );
        // Same grid validation the JSON plane runs: malformed buckets are
        // a build error, not a GraphCache panic mid-setup.
        crate::coordinator::GraphCache::try_new(&cfg.decode_buckets, &cfg.offload_buckets, None)
            .map(|_| ())?;
        if let Some(f) = &cfg.fleet {
            anyhow::ensure!(f.groups >= 1, "fleet groups must be >= 1");
            anyhow::ensure!(
                f.group_profiles.len() <= f.groups as usize,
                "fleet group_profiles lists {} entries for {} groups",
                f.group_profiles.len(),
                f.groups
            );
            for p in f.group_profiles.iter().flatten() {
                for dp in [p.prefill, p.decode, p.executor].into_iter().flatten() {
                    if let Some(s) = dp.sm_frac {
                        anyhow::ensure!(
                            s.is_finite() && s > 0.0 && s <= 1.0,
                            "device profile sm_frac must be in (0, 1], got {s}"
                        );
                    }
                }
            }
            if let Some(s) = &f.autoscale {
                anyhow::ensure!(s.min_prefill >= 1, "autoscale min_prefill must be >= 1");
                anyhow::ensure!(
                    s.max_prefill >= s.min_prefill,
                    "autoscale max_prefill must be >= min_prefill"
                );
                anyhow::ensure!(
                    s.tick_s.is_finite() && s.tick_s > 0.0,
                    "autoscale tick_s must be positive and finite"
                );
                anyhow::ensure!(
                    s.sustain_s.is_finite() && s.sustain_s >= 0.0,
                    "autoscale sustain_s must be finite and >= 0"
                );
                anyhow::ensure!(
                    s.cooldown_s.is_finite() && s.cooldown_s >= 0.0,
                    "autoscale cooldown_s must be finite and >= 0"
                );
            }
            if let Some(s) = &f.overload {
                anyhow::ensure!(
                    s.ttft_budget_s.is_finite() && s.ttft_budget_s > 0.0,
                    "overload ttft_budget_s must be positive and finite"
                );
                anyhow::ensure!(
                    s.retry_backoff_s.is_finite() && s.retry_backoff_s > 0.0,
                    "overload retry_backoff_s must be positive and finite"
                );
                anyhow::ensure!(
                    s.retry_backoff_cap_s.is_finite()
                        && s.retry_backoff_cap_s >= s.retry_backoff_s,
                    "overload retry_backoff_cap_s must be finite and >= retry_backoff_s"
                );
            }
        }
        if let Some(ft) = &cfg.fault {
            for sf in &ft.script {
                if let Some(g) = sf.group {
                    let groups = cfg.fleet.as_ref().map_or(0, |f| f.groups);
                    anyhow::ensure!(
                        g < groups,
                        "scripted fault targets group {g} but the config has {groups} \
                         fleet group(s)"
                    );
                }
            }
        }
        if let Some(r) = &cfg.rebalance {
            anyhow::ensure!(
                r.interval_s.is_finite() && r.interval_s > 0.0,
                "rebalance interval_s must be positive and finite"
            );
        }
        if let Some(f) = &cfg.bounds_feedback {
            anyhow::ensure!(
                f.interval_s.is_finite() && f.interval_s > 0.0,
                "bounds_feedback interval_s must be positive and finite"
            );
            anyhow::ensure!(
                f.alpha > 0.0 && f.alpha <= 1.0,
                "bounds_feedback alpha must be in (0, 1]"
            );
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_load_aware() {
        assert_eq!(ServingConfig::default().offload, OffloadPolicy::LoadAware);
        assert!(ServingConfig::default().offload.is_enabled());
    }

    #[test]
    fn baseline_disables_offload() {
        assert!(!ServingConfig::baseline().offload.is_enabled());
    }

    #[test]
    fn fixed_zero_ratio_counts_as_disabled() {
        assert!(!OffloadPolicy::FixedRatio(0.0).is_enabled());
        assert!(OffloadPolicy::FixedRatio(0.7).is_enabled());
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            ServingConfig::default(),
            ServingConfig::baseline(),
            ServingConfig { offload: OffloadPolicy::FixedRatio(0.7), ..Default::default() },
            ServingConfig { rebalance: Some(RebalanceConfig::default()), ..Default::default() },
            ServingConfig {
                rebalance: Some(RebalanceConfig {
                    interval_s: 0.5,
                    hysteresis: 0.1,
                    max_migrations_per_interval: 4,
                }),
                ..Default::default()
            },
            ServingConfig {
                bounds_feedback: Some(BoundsFeedbackConfig::default()),
                ..Default::default()
            },
            ServingConfig {
                bounds_feedback: Some(BoundsFeedbackConfig {
                    interval_s: 1.0,
                    alpha: 0.5,
                    min_observations: 4,
                }),
                rebalance: Some(RebalanceConfig::default()),
                ..Default::default()
            },
            ServingConfig { fault: Some(FaultConfig::default()), ..Default::default() },
            ServingConfig {
                fault: Some(FaultConfig {
                    script: vec![
                        ScriptedFault {
                            kind: FaultKind::PrefillCrash,
                            instance: 0,
                            at_s: 10.0,
                            down_s: 5.0,
                            group: None,
                        },
                        ScriptedFault {
                            kind: FaultKind::Straggler,
                            instance: 1,
                            at_s: 20.0,
                            down_s: 8.0,
                            group: None,
                        },
                    ],
                    prefill_mtbf_s: Some(60.0),
                    decode_mtbf_s: Some(90.0),
                    transfer_fail_prob: 0.1,
                    health_aware: false,
                    ..Default::default()
                }),
                ..Default::default()
            },
            ServingConfig {
                fault: Some(FaultConfig {
                    script: vec![ScriptedFault {
                        kind: FaultKind::PrefillCrash,
                        instance: 0,
                        at_s: 30.0,
                        down_s: 60.0,
                        group: Some(1),
                    }],
                    ..Default::default()
                }),
                fleet: Some(FleetConfig { groups: 2, ..Default::default() }),
                ..Default::default()
            },
        ] {
            let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn json_partial_overrides_defaults() {
        let cfg = ServingConfig::from_json(r#"{"max_batch": 32, "offload": 0.5}"#).unwrap();
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.offload, OffloadPolicy::FixedRatio(0.5));
        assert_eq!(cfg.kv_block_tokens, ServingConfig::default().kv_block_tokens);
        assert!(!cfg.exact_costs, "bucketed costs are the default");
    }

    #[test]
    fn json_exact_costs_roundtrip() {
        let cfg = ServingConfig::from_json(r#"{"exact_costs": true}"#).unwrap();
        assert!(cfg.exact_costs);
        let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn json_no_leap_roundtrip_and_defaults_off() {
        assert!(!ServingConfig::default().no_leap, "leaping is the default");
        let cfg = ServingConfig::from_json(r#"{"no_leap": true}"#).unwrap();
        assert!(cfg.no_leap);
        let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        let off = ServingConfig::from_json(r#"{"no_leap": false}"#).unwrap();
        assert!(!off.no_leap);
    }

    #[test]
    fn json_no_par_roundtrip_and_defaults_off() {
        assert!(!ServingConfig::default().no_par, "within-run parallelism is the default");
        assert_eq!(ServingConfig::default().par_workers, 0, "pool auto-sizes by default");
        let cfg = ServingConfig::from_json(r#"{"no_par": true, "par_workers": 4}"#).unwrap();
        assert!(cfg.no_par);
        assert_eq!(cfg.par_workers, 4);
        let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        let off = ServingConfig::from_json(r#"{"no_par": false}"#).unwrap();
        assert!(!off.no_par);
    }

    #[test]
    fn rebalance_defaults_off_and_partial_json_fills_defaults() {
        assert!(ServingConfig::default().rebalance.is_none(), "rebalancing is opt-in");
        let cfg = ServingConfig::from_json(r#"{"rebalance": {"interval_s": 1.0}}"#).unwrap();
        let r = cfg.rebalance.expect("rebalance object enables the controller");
        assert_eq!(r.interval_s, 1.0);
        assert_eq!(r.hysteresis, RebalanceConfig::default().hysteresis);
        assert_eq!(
            r.max_migrations_per_interval,
            RebalanceConfig::default().max_migrations_per_interval
        );
        assert!(ServingConfig::from_json(r#"{"rebalance": {"interval_s": 0}}"#).is_err());
        // null is the spelled-out "off"; non-objects are config errors,
        // never silently-enabled defaults.
        let off = ServingConfig::from_json(r#"{"rebalance": null}"#).unwrap();
        assert!(off.rebalance.is_none());
        assert!(ServingConfig::from_json(r#"{"rebalance": true}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"rebalance": 0.25}"#).is_err());
        // Wrong-typed fields are errors, never silent defaults.
        assert!(ServingConfig::from_json(r#"{"rebalance": {"interval_s": "fast"}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"rebalance": {"max_migrations": 0.5}}"#).is_err());
    }

    #[test]
    fn bounds_feedback_defaults_off_and_json_validates() {
        assert!(ServingConfig::default().bounds_feedback.is_none(), "feedback is opt-in");
        let cfg =
            ServingConfig::from_json(r#"{"bounds_feedback": {"interval_s": 0.5}}"#).unwrap();
        let f = cfg.bounds_feedback.expect("object enables the feedback plane");
        assert_eq!(f.interval_s, 0.5);
        assert_eq!(f.alpha, BoundsFeedbackConfig::default().alpha);
        assert_eq!(f.min_observations, BoundsFeedbackConfig::default().min_observations);
        // null spells "off"; malformed values are errors, never silent
        // defaults.
        let off = ServingConfig::from_json(r#"{"bounds_feedback": null}"#).unwrap();
        assert!(off.bounds_feedback.is_none());
        assert!(ServingConfig::from_json(r#"{"bounds_feedback": true}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"bounds_feedback": {"interval_s": 0}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"bounds_feedback": {"alpha": 0}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"bounds_feedback": {"alpha": 1.5}}"#).is_err());
        // Wrong-typed fields are errors too, never silent defaults.
        assert!(
            ServingConfig::from_json(r#"{"bounds_feedback": {"interval_s": "fast"}}"#).is_err()
        );
        assert!(
            ServingConfig::from_json(r#"{"bounds_feedback": {"interval_s": 1e400}}"#).is_err(),
            "non-finite interval must be a config error, not a runtime panic"
        );
        assert!(
            ServingConfig::from_json(r#"{"bounds_feedback": {"min_observations": -1}}"#).is_err()
        );
        assert!(
            ServingConfig::from_json(r#"{"bounds_feedback": {"min_observations": 1.5}}"#).is_err()
        );
    }

    #[test]
    fn fault_defaults_off_and_json_validates() {
        assert!(ServingConfig::default().fault.is_none(), "fault injection is opt-in");
        let cfg = ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "decode_crash", "instance": 0, "at_s": 5, "down_s": 2}]}}"#,
        )
        .unwrap();
        let f = cfg.fault.expect("object enables the fault plane");
        assert_eq!(f.script.len(), 1);
        assert_eq!(f.script[0].kind, FaultKind::DecodeCrash);
        assert_eq!(f.script[0].at_s, 5.0);
        assert_eq!(f.heartbeat_s, FaultConfig::default().heartbeat_s);
        assert!(f.health_aware, "graceful degradation is the default");
        // null spells "off"; malformed values are errors, never silent
        // defaults.
        let off = ServingConfig::from_json(r#"{"fault": null}"#).unwrap();
        assert!(off.fault.is_none());
        assert!(ServingConfig::from_json(r#"{"fault": true}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"script": 3}}"#).is_err());
        assert!(ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "meteor", "instance": 0, "at_s": 1, "down_s": 1}]}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "straggler", "instance": 0, "at_s": -1, "down_s": 1}]}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "straggler", "instance": 0, "at_s": 1, "down_s": 0}]}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"prefill_mtbf_s": 0}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"prefill_mttr_s": 0}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"transfer_fail_prob": 1.5}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"transfer_max_retries": 0.5}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"transfer_backoff_s": 0}}"#).is_err());
        assert!(ServingConfig::from_json(
            r#"{"fault": {"transfer_backoff_s": 1.0, "transfer_backoff_cap_s": 0.5}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"straggler_factor": 0.5}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"heartbeat_s": 0}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fault": {"health_aware": "yes"}}"#).is_err());
        // MTBF null spells "off" inside the object too.
        let f = ServingConfig::from_json(r#"{"fault": {"prefill_mtbf_s": null}}"#)
            .unwrap()
            .fault
            .unwrap();
        assert!(f.prefill_mtbf_s.is_none());
        // Group scoping (ISSUE 10): null/absent = every group; Some(g)
        // needs a fleet with more groups than g.
        assert!(f.script.is_empty() || f.script.iter().all(|s| s.group.is_none()));
        assert!(ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "prefill_crash", "instance": 0, "at_s": 1,
                "down_s": 1, "group": 0}]}}"#
        )
        .is_err(), "group-scoped faults require a fleet");
        assert!(ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "prefill_crash", "instance": 0, "at_s": 1,
                "down_s": 1, "group": 2}]},
                "fleet": {"groups": 2}}"#
        )
        .is_err(), "fault group must be < fleet groups");
        let scoped = ServingConfig::from_json(
            r#"{"fault": {"script": [{"kind": "prefill_crash", "instance": 0, "at_s": 1,
                "down_s": 1, "group": 1}]},
                "fleet": {"groups": 2}}"#,
        )
        .unwrap();
        assert_eq!(scoped.fault.unwrap().script[0].group, Some(1));
    }

    #[test]
    fn bad_bucket_grid_fails_at_json_validation_not_midsetup() {
        // Satellite: a malformed executable-bucket grid must surface as a
        // proper Err from config parsing, not a GraphCache::new panic when
        // the sim or server is later constructed.
        assert!(ServingConfig::from_json(r#"{"decode_buckets": []}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"offload_buckets": [0, 2]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"decode_buckets": [4, 2]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"decode_buckets": [2, 2, 4]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"decode_buckets": [1, 2, 4, 8]}"#).is_ok());
    }

    #[test]
    fn fleet_defaults_off_and_json_validates() {
        assert!(ServingConfig::default().fleet.is_none(), "the fleet layer is opt-in");
        let cfg = ServingConfig::from_json(
            r#"{"fleet": {"groups": 4, "router": "least_loaded"}}"#,
        )
        .unwrap();
        let f = cfg.fleet.expect("object enables the fleet layer");
        assert_eq!(f.groups, 4);
        assert_eq!(f.router, RouterPolicy::LeastLoaded);
        assert!(f.autoscale.is_none(), "autoscale is opt-in inside the fleet object");
        // null spells "off"; malformed values are errors, never silent
        // defaults.
        let off = ServingConfig::from_json(r#"{"fleet": null}"#).unwrap();
        assert!(off.fleet.is_none());
        assert!(ServingConfig::from_json(r#"{"fleet": true}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": {"groups": 0}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": {"router": "chaotic"}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": {"groups": 1.5}}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": {"autoscale": 3}}"#).is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"autoscale": {"min_prefill": 0}}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"autoscale": {"min_prefill": 3, "max_prefill": 2}}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": {"autoscale": {"tick_s": 0}}}"#).is_err());
        let with_scale = ServingConfig::from_json(
            r#"{"fleet": {"groups": 2, "autoscale": {"min_prefill": 1, "max_prefill": 3,
                "initial_prefill": 2, "scale_up_pressure": 0.8, "tick_s": 0.25}}}"#,
        )
        .unwrap();
        let s = with_scale.fleet.unwrap().autoscale.unwrap();
        assert_eq!(s.min_prefill, 1);
        assert_eq!(s.max_prefill, 3);
        assert_eq!(s.initial_prefill, Some(2));
        assert_eq!(s.scale_up_pressure, 0.8);
        assert_eq!(s.tick_s, 0.25);
        assert_eq!(s.cooldown_s, AutoscaleConfig::default().cooldown_s);
    }

    #[test]
    fn overload_defaults_off_and_json_validates() {
        assert!(
            FleetConfig::default().overload.is_none(),
            "admission control is opt-in inside the fleet object"
        );
        let cfg = ServingConfig::from_json(
            r#"{"fleet": {"groups": 2, "overload": {"ttft_budget_s": 1.5}}}"#,
        )
        .unwrap();
        let ov = cfg.fleet.unwrap().overload.expect("object enables admission control");
        assert_eq!(ov.ttft_budget_s, 1.5);
        assert_eq!(ov.max_retries, OverloadConfig::default().max_retries);
        assert_eq!(ov.retry_backoff_s, OverloadConfig::default().retry_backoff_s);
        // null spells "off"; malformed values are errors, never silent
        // defaults.
        let off = ServingConfig::from_json(r#"{"fleet": {"overload": null}}"#).unwrap();
        assert!(off.fleet.unwrap().overload.is_none());
        assert!(ServingConfig::from_json(r#"{"fleet": {"overload": true}}"#).is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"overload": {"ttft_budget_s": 0}}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"overload": {"ttft_budget_s": 1e400}}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"overload": {"max_retries": 0.5}}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"overload": {"retry_backoff_s": 0}}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"overload": {"retry_backoff_s": 1.0, "retry_backoff_cap_s": 0.5}}}"#
        )
        .is_err());
        // max_retries 0 is legal: shed immediately, no retry queue.
        let strict = ServingConfig::from_json(
            r#"{"fleet": {"overload": {"max_retries": 0}}}"#,
        )
        .unwrap();
        assert_eq!(strict.fleet.unwrap().overload.unwrap().max_retries, 0);
    }

    #[test]
    fn fleet_json_roundtrip() {
        for cfg in [
            ServingConfig { fleet: Some(FleetConfig::default()), ..Default::default() },
            ServingConfig {
                fleet: Some(FleetConfig {
                    groups: 4,
                    router: RouterPolicy::SessionSticky,
                    autoscale: Some(AutoscaleConfig {
                        min_prefill: 1,
                        max_prefill: 3,
                        initial_prefill: Some(2),
                        ..Default::default()
                    }),
                    ..Default::default()
                }),
                ..Default::default()
            },
            ServingConfig {
                fleet: Some(FleetConfig {
                    groups: 2,
                    router: RouterPolicy::LeastLoaded,
                    overload: Some(OverloadConfig {
                        ttft_budget_s: 1.25,
                        max_retries: 3,
                        retry_backoff_s: 0.1,
                        retry_backoff_cap_s: 0.8,
                    }),
                    ..Default::default()
                }),
                ..Default::default()
            },
            ServingConfig {
                fleet: Some(FleetConfig {
                    groups: 3,
                    group_profiles: vec![
                        None,
                        Some(DeviceProfiles {
                            prefill: Some(DeviceProfile::partitioned(
                                GpuSpec::a100_80g(),
                                DeviceRole::Prefill,
                                0.45,
                            )),
                            decode: None,
                            executor: Some(DeviceProfile::whole(
                                GpuSpec::h20_96g(),
                                DeviceRole::Executor,
                            )),
                        }),
                    ],
                    ..Default::default()
                }),
                ..Default::default()
            },
        ] {
            let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn fleet_group_profiles_rejects_bad_shapes() {
        // More profile entries than groups.
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"groups": 1, "group_profiles": [null, null]}}"#
        )
        .is_err());
        // Unknown GPU preset.
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"groups": 1, "group_profiles": [{"decode": {"gpu": "TPUv9"}}]}}"#
        )
        .is_err());
        // sm_frac out of (0, 1].
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"groups": 1,
                "group_profiles": [{"prefill": {"gpu": "A100-80GB-SXM", "sm_frac": 1.5}}]}}"#
        )
        .is_err());
        // Wrong-typed entry and wrong-typed slot are errors, not skips.
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"groups": 1, "group_profiles": [7]}}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"fleet": {"groups": 1, "group_profiles": [{"executor": 7}]}}"#
        )
        .is_err());
        // A valid heterogeneous entry parses.
        let cfg = ServingConfig::from_json(
            r#"{"fleet": {"groups": 2,
                "group_profiles": [null, {"executor": {"gpu": "H20-96GB", "sm_frac": null}}]}}"#,
        )
        .unwrap();
        let f = cfg.fleet.expect("fleet configured");
        assert_eq!(f.group_profiles.len(), 2);
        assert_eq!(f.group_profiles[0], None);
        let p = f.group_profiles[1].expect("profiles for group 1");
        assert_eq!(p.executor.expect("executor slot").gpu, GpuSpec::h20_96g());
        assert_eq!(p.prefill, None);
    }

    #[test]
    fn builder_defaults_equal_default() {
        assert_eq!(ServingConfig::builder().build().unwrap(), ServingConfig::default());
    }

    #[test]
    fn builder_stages_and_validates() {
        let cfg = ServingConfig::builder()
            .offload(OffloadPolicy::FixedRatio(0.5))
            .max_batch(64)
            .no_leap(true)
            .fleet(FleetConfig { groups: 2, ..Default::default() })
            .build()
            .unwrap();
        assert_eq!(cfg.offload, OffloadPolicy::FixedRatio(0.5));
        assert_eq!(cfg.max_batch, 64);
        assert!(cfg.no_leap);
        assert_eq!(cfg.fleet.unwrap().groups, 2);
    }

    #[test]
    fn builder_rejects_contradictions() {
        // par_workers with no_par is a contradiction, not a silent pick.
        assert!(ServingConfig::builder().no_par(true).par_workers(4).build().is_err());
        // par_workers == 1 *means* serial pricing, so it composes.
        assert!(ServingConfig::builder().no_par(true).par_workers(1).build().is_ok());
        // Zero-group fleets and inverted autoscale ranges are errors.
        assert!(ServingConfig::builder()
            .fleet(FleetConfig { groups: 0, ..Default::default() })
            .build()
            .is_err());
        assert!(ServingConfig::builder()
            .fleet(FleetConfig {
                groups: 1,
                router: RouterPolicy::RoundRobin,
                autoscale: Some(AutoscaleConfig {
                    min_prefill: 4,
                    max_prefill: 2,
                    ..Default::default()
                }),
                ..Default::default()
            })
            .build()
            .is_err());
        // More group_profiles entries than groups is a build error too.
        assert!(ServingConfig::builder()
            .fleet(FleetConfig {
                groups: 1,
                group_profiles: vec![None, None],
                ..Default::default()
            })
            .build()
            .is_err());
        // Malformed bucket grids fail at build, not mid-setup.
        assert!(ServingConfig::builder().decode_buckets(vec![4, 2]).build().is_err());
        assert!(ServingConfig::builder().max_batch(0).build().is_err());
        // Overload knobs validate at build too (ISSUE 10).
        assert!(ServingConfig::builder()
            .fleet(FleetConfig {
                groups: 2,
                overload: Some(OverloadConfig { ttft_budget_s: 0.0, ..Default::default() }),
                ..Default::default()
            })
            .build()
            .is_err());
        assert!(ServingConfig::builder()
            .fleet(FleetConfig {
                groups: 2,
                overload: Some(OverloadConfig {
                    retry_backoff_s: 1.0,
                    retry_backoff_cap_s: 0.5,
                    ..Default::default()
                }),
                ..Default::default()
            })
            .build()
            .is_err());
        // Group-scoped scripted faults need a fleet that contains the group.
        let scoped = FaultConfig {
            script: vec![ScriptedFault {
                kind: FaultKind::PrefillCrash,
                instance: 0,
                at_s: 1.0,
                down_s: 1.0,
                group: Some(1),
            }],
            ..Default::default()
        };
        assert!(ServingConfig::builder().fault(scoped.clone()).build().is_err());
        assert!(ServingConfig::builder()
            .fault(scoped.clone())
            .fleet(FleetConfig { groups: 1, ..Default::default() })
            .build()
            .is_err());
        assert!(ServingConfig::builder()
            .fault(scoped)
            .fleet(FleetConfig { groups: 2, ..Default::default() })
            .build()
            .is_ok());
    }

    #[test]
    fn json_file_load(){
        let dir = std::env::temp_dir().join("adrenaline_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, ServingConfig::baseline().to_json()).unwrap();
        let cfg = ServingConfig::from_json_file(&path).unwrap();
        assert_eq!(cfg.offload, OffloadPolicy::Disabled);
    }
}
