//! Workload generation: synthetic equivalents of the paper's datasets.
//!
//! The paper evaluates on ShareGPT (chatbot: moderate prompts, moderate
//! outputs) and OpenThoughts (reasoning: short prompts, long
//! chain-of-thought outputs, output/prompt ratio ≫ 1). The text content is
//! irrelevant to a serving system — every figure depends only on the
//! (prompt_len, output_len) joint distribution and the arrival process —
//! so we generate seeded synthetic traces matching the published length
//! statistics. See DESIGN.md §1.

mod generator;
mod request;
pub mod trace;

pub use generator::{ArrivalPattern, TraceGenerator, WorkloadKind};
pub use request::{Request, RequestId};
pub use trace::{load_trace, save_trace, trace_from_json, trace_to_json};
