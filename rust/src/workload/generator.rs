//! Seeded synthetic trace generation matching the paper's two datasets.
//!
//! Length statistics (tokens), drawn from the published characterizations
//! of each dataset (ShareGPT: vLLM/DistServe sampling convention;
//! OpenThoughts: long chain-of-thought outputs with short prompts):
//!
//! | dataset       | prompt (median≈) | output (median≈) | output/prompt |
//! |---------------|------------------|------------------|---------------|
//! | ShareGPT      | ~220             | ~180             | ≈ 1           |
//! | OpenThoughts  | ~120             | ~1600            | ≫ 1           |
//!
//! Lengths are log-normal (the standard fit for both corpora), clipped to
//! sane ranges; arrivals are Poisson at a configurable rate — exactly the
//! process the paper's request-rate sweeps use. Everything is seeded and
//! replayable (see `util::rng`).

use crate::util::rng::Rng;

use super::request::Request;

/// Which dataset's length statistics to synthesize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Chatbot traffic (ShareGPT-like).
    ShareGpt,
    /// Reasoning traffic (OpenThoughts-like): short prompts, very long
    /// outputs — the preemption-heavy case in Figs 13/14.
    OpenThoughts,
    /// Fixed lengths (microbenchmarks and unit tests).
    Fixed { prompt: usize, output: usize },
}

impl WorkloadKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::ShareGpt => "sharegpt",
            WorkloadKind::OpenThoughts => "openthoughts",
            WorkloadKind::Fixed { .. } => "fixed",
        }
    }

    /// (mu, sigma) of ln(prompt_len), ln(output_len).
    fn lognormal_params(&self) -> ((f64, f64), (f64, f64)) {
        match self {
            // median 220 prompt / 180 output, moderate spread.
            WorkloadKind::ShareGpt => ((220f64.ln(), 0.95), (180f64.ln(), 0.85)),
            // median 120 prompt / 1600 output, heavier output tail.
            WorkloadKind::OpenThoughts => ((120f64.ln(), 0.60), (1600f64.ln(), 0.45)),
            WorkloadKind::Fixed { .. } => unreachable!("fixed lengths don't sample"),
        }
    }
}

/// Poisson-arrival trace generator.
#[derive(Debug)]
pub struct TraceGenerator {
    kind: WorkloadKind,
    /// Mean request rate, req/s.
    rate: f64,
    /// Clip range for prompt lengths (inclusive).
    prompt_clip: (usize, usize),
    /// Clip range for output lengths (inclusive).
    output_clip: (usize, usize),
    rng: Rng,
    next_id: u64,
    clock_s: f64,
}

impl TraceGenerator {
    pub fn new(kind: WorkloadKind, rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        TraceGenerator {
            kind,
            rate,
            prompt_clip: (4, 8192),
            output_clip: (1, 8192),
            rng: Rng::seed_from_u64(seed),
            next_id: 0,
            clock_s: 0.0,
        }
    }

    /// Clip ranges for the *tiny* CPU-path model (max_seq_len 128).
    pub fn with_clip(mut self, prompt: (usize, usize), output: (usize, usize)) -> Self {
        assert!(prompt.0 >= 1 && prompt.0 <= prompt.1);
        assert!(output.0 >= 1 && output.0 <= output.1);
        self.prompt_clip = prompt;
        self.output_clip = output;
        self
    }

    fn sample_len(rng: &mut Rng, mu: f64, sigma: f64, clip: (usize, usize)) -> usize {
        (rng.lognormal(mu, sigma).round() as usize).clamp(clip.0, clip.1)
    }

    /// Generate the next request (arrivals strictly increase).
    pub fn next_request(&mut self) -> Request {
        self.clock_s += self.rng.exp(self.rate);
        let (prompt_len, output_len) = match self.kind {
            WorkloadKind::Fixed { prompt, output } => (prompt, output),
            kind => {
                let ((pm, ps), (om, os)) = kind.lognormal_params();
                (
                    Self::sample_len(&mut self.rng, pm, ps, self.prompt_clip),
                    Self::sample_len(&mut self.rng, om, os, self.output_clip),
                )
            }
        };
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, self.clock_s, prompt_len, output_len)
    }

    /// Generate a trace covering `duration_s` seconds.
    pub fn trace(&mut self, duration_s: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let r = self.next_request();
            if r.arrival_s > duration_s {
                break;
            }
            out.push(r);
        }
        out
    }

    /// Generate exactly `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Attach random prompt token ids (for the real CPU path).
    pub fn with_tokens(&mut self, mut reqs: Vec<Request>, vocab: u32) -> Vec<Request> {
        for r in &mut reqs {
            r.prompt_tokens = (0..r.prompt_len)
                .map(|_| self.rng.range_u64(0, vocab as u64) as u32)
                .collect();
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn median(mut v: Vec<usize>) -> usize {
        v.sort_unstable();
        v[v.len() / 2]
    }

    #[test]
    fn deterministic_given_seed() {
        let a = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 42).take(50);
        let b = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 42).take(50);
        assert_eq!(a, b);
        let c = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 43).take(50);
        assert_ne!(a, c);
    }

    #[test]
    fn arrivals_strictly_increase_at_mean_rate() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 4.0, 1).take(2000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        let span = reqs.last().unwrap().arrival_s;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 4.0).abs() / 4.0 < 0.1, "empirical rate {rate}");
    }

    #[test]
    fn sharegpt_length_statistics() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 1.0, 7).take(4000);
        let pm = median(reqs.iter().map(|r| r.prompt_len).collect());
        let om = median(reqs.iter().map(|r| r.output_len).collect());
        assert!((150..300).contains(&pm), "prompt median {pm}");
        assert!((120..260).contains(&om), "output median {om}");
    }

    #[test]
    fn openthoughts_output_dominates_prompt() {
        let reqs = TraceGenerator::new(WorkloadKind::OpenThoughts, 1.0, 7).take(4000);
        let pm = median(reqs.iter().map(|r| r.prompt_len).collect()) as f64;
        let om = median(reqs.iter().map(|r| r.output_len).collect()) as f64;
        assert!(om / pm > 5.0, "output/prompt ratio = {}", om / pm);
    }

    #[test]
    fn clip_respected() {
        let reqs = TraceGenerator::new(WorkloadKind::OpenThoughts, 1.0, 3)
            .with_clip((4, 48), (1, 64))
            .take(500);
        for r in &reqs {
            assert!((4..=48).contains(&r.prompt_len));
            assert!((1..=64).contains(&r.output_len));
        }
    }

    #[test]
    fn fixed_kind_is_fixed() {
        let reqs =
            TraceGenerator::new(WorkloadKind::Fixed { prompt: 32, output: 16 }, 1.0, 0).take(10);
        assert!(reqs.iter().all(|r| r.prompt_len == 32 && r.output_len == 16));
    }

    #[test]
    fn trace_bounded_by_duration() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 10.0, 5).trace(3.0);
        assert!(!reqs.is_empty());
        assert!(reqs.iter().all(|r| r.arrival_s <= 3.0));
    }

    #[test]
    fn with_tokens_populates_prompt_ids() {
        let mut g = TraceGenerator::new(WorkloadKind::Fixed { prompt: 8, output: 4 }, 1.0, 0);
        let reqs = g.take(3);
        let reqs = g.with_tokens(reqs, 256);
        for r in &reqs {
            assert_eq!(r.prompt_tokens.len(), 8);
            assert!(r.prompt_tokens.iter().all(|&t| t < 256));
        }
    }
}
