//! Seeded synthetic trace generation matching the paper's two datasets.
//!
//! Length statistics (tokens), drawn from the published characterizations
//! of each dataset (ShareGPT: vLLM/DistServe sampling convention;
//! OpenThoughts: long chain-of-thought outputs with short prompts):
//!
//! | dataset       | prompt (median≈) | output (median≈) | output/prompt |
//! |---------------|------------------|------------------|---------------|
//! | ShareGPT      | ~220             | ~180             | ≈ 1           |
//! | OpenThoughts  | ~120             | ~1600            | ≫ 1           |
//!
//! Lengths are log-normal (the standard fit for both corpora), clipped to
//! sane ranges; arrivals default to homogeneous Poisson at a configurable
//! rate — exactly the process the paper's request-rate sweeps use — and
//! can be modulated into bursty (on/off MMPP) or diurnal (sinusoidal)
//! non-stationary processes for the rebalancer scenarios
//! (EXPERIMENTS.md §Scenarios). Everything is seeded and replayable (see
//! `util::rng`).

use crate::util::rng::Rng;

use super::request::Request;

/// Which dataset's length statistics to synthesize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Chatbot traffic (ShareGPT-like).
    ShareGpt,
    /// Reasoning traffic (OpenThoughts-like): short prompts, very long
    /// outputs — the preemption-heavy case in Figs 13/14.
    OpenThoughts,
    /// Fixed lengths (microbenchmarks and unit tests).
    Fixed { prompt: usize, output: usize },
}

impl WorkloadKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::ShareGpt => "sharegpt",
            WorkloadKind::OpenThoughts => "openthoughts",
            WorkloadKind::Fixed { .. } => "fixed",
        }
    }

    /// (mu, sigma) of ln(prompt_len), ln(output_len).
    fn lognormal_params(&self) -> ((f64, f64), (f64, f64)) {
        match self {
            // median 220 prompt / 180 output, moderate spread.
            WorkloadKind::ShareGpt => ((220f64.ln(), 0.95), (180f64.ln(), 0.85)),
            // median 120 prompt / 1600 output, heavier output tail.
            WorkloadKind::OpenThoughts => ((120f64.ln(), 0.60), (1600f64.ln(), 0.45)),
            WorkloadKind::Fixed { .. } => unreachable!("fixed lengths don't sample"),
        }
    }
}

/// Shape of the arrival process (EXPERIMENTS.md §Scenarios).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Homogeneous Poisson arrivals at the configured mean rate — the
    /// default, bit-identical to the pre-pattern generator (one
    /// exponential draw per arrival).
    Poisson,
    /// On/off modulated Poisson (MMPP): the rate is `mult × rate` for the
    /// first `duty` fraction of each `period_s`-second cycle and a
    /// compensating low rate for the rest, so the *mean* offered load
    /// stays at `rate` (requires `duty · mult < 1`). Sampled exactly via
    /// the memorylessness of the exponential: a draw that crosses a
    /// segment boundary restarts from the boundary at the new rate.
    Bursty { period_s: f64, duty: f64, mult: f64 },
    /// Sinusoidal diurnal modulation, `λ(t) = rate·(1 + depth·sin(2πt/T))`,
    /// sampled by Lewis–Shedler thinning against `λ_max = rate·(1+depth)`.
    Diurnal { period_s: f64, depth: f64 },
}

impl ArrivalPattern {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::Poisson => "poisson",
            ArrivalPattern::Bursty { .. } => "bursty",
            ArrivalPattern::Diurnal { .. } => "diurnal",
        }
    }

    fn validate(&self) {
        match *self {
            ArrivalPattern::Poisson => {}
            ArrivalPattern::Bursty { period_s, duty, mult } => {
                assert!(period_s > 0.0, "bursty period must be positive");
                assert!((0.0..1.0).contains(&duty) && duty > 0.0, "duty in (0,1)");
                assert!(mult >= 1.0, "burst multiplier must be >= 1");
                assert!(
                    duty * mult < 1.0,
                    "duty*mult must be < 1 so the trough rate stays positive"
                );
            }
            ArrivalPattern::Diurnal { period_s, depth } => {
                assert!(period_s > 0.0, "diurnal period must be positive");
                assert!((0.0..=1.0).contains(&depth), "depth in [0,1]");
            }
        }
    }
}

/// Poisson-arrival trace generator (optionally rate-modulated; see
/// [`ArrivalPattern`]).
#[derive(Debug)]
pub struct TraceGenerator {
    kind: WorkloadKind,
    /// Mean request rate, req/s.
    rate: f64,
    arrivals: ArrivalPattern,
    /// Clip range for prompt lengths (inclusive).
    prompt_clip: (usize, usize),
    /// Clip range for output lengths (inclusive).
    output_clip: (usize, usize),
    rng: Rng,
    next_id: u64,
    clock_s: f64,
}

impl TraceGenerator {
    pub fn new(kind: WorkloadKind, rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        TraceGenerator {
            kind,
            rate,
            arrivals: ArrivalPattern::Poisson,
            prompt_clip: (4, 8192),
            output_clip: (1, 8192),
            rng: Rng::seed_from_u64(seed),
            next_id: 0,
            clock_s: 0.0,
        }
    }

    /// Clip ranges for the *tiny* CPU-path model (max_seq_len 128).
    pub fn with_clip(mut self, prompt: (usize, usize), output: (usize, usize)) -> Self {
        assert!(prompt.0 >= 1 && prompt.0 <= prompt.1);
        assert!(output.0 >= 1 && output.0 <= output.1);
        self.prompt_clip = prompt;
        self.output_clip = output;
        self
    }

    /// Select the arrival process. `Poisson` (the default) consumes the
    /// RNG exactly like the pre-pattern generator, so existing seeded
    /// traces are unchanged.
    pub fn with_arrivals(mut self, arrivals: ArrivalPattern) -> Self {
        arrivals.validate();
        self.arrivals = arrivals;
        self
    }

    fn sample_len(rng: &mut Rng, mu: f64, sigma: f64, clip: (usize, usize)) -> usize {
        (rng.lognormal(mu, sigma).round() as usize).clamp(clip.0, clip.1)
    }

    /// Advance the clock to the next arrival instant.
    fn advance_clock(&mut self) {
        match self.arrivals {
            ArrivalPattern::Poisson => self.clock_s += self.rng.exp(self.rate),
            ArrivalPattern::Bursty { period_s, duty, mult } => {
                let burst_len = duty * period_s;
                let high = self.rate * mult;
                let low = self.rate * (1.0 - duty * mult) / (1.0 - duty);
                loop {
                    let phase = self.clock_s % period_s;
                    let (lam, seg_end) = if phase < burst_len {
                        (high, self.clock_s - phase + burst_len)
                    } else {
                        (low, self.clock_s - phase + period_s)
                    };
                    let gap = self.rng.exp(lam);
                    if self.clock_s + gap <= seg_end {
                        self.clock_s += gap;
                        return;
                    }
                    // The draw crossed the boundary: by memorylessness the
                    // residual restarts at the boundary under the new rate.
                    self.clock_s = seg_end;
                }
            }
            ArrivalPattern::Diurnal { period_s, depth } => {
                let lam_max = self.rate * (1.0 + depth);
                loop {
                    self.clock_s += self.rng.exp(lam_max);
                    let lam_t = self.rate
                        * (1.0
                            + depth
                                * (std::f64::consts::TAU * self.clock_s / period_s).sin());
                    if self.rng.f64() * lam_max <= lam_t {
                        return;
                    }
                }
            }
        }
    }

    /// Generate the next request (arrivals strictly increase).
    pub fn next_request(&mut self) -> Request {
        self.advance_clock();
        let (prompt_len, output_len) = match self.kind {
            WorkloadKind::Fixed { prompt, output } => (prompt, output),
            kind => {
                let ((pm, ps), (om, os)) = kind.lognormal_params();
                (
                    Self::sample_len(&mut self.rng, pm, ps, self.prompt_clip),
                    Self::sample_len(&mut self.rng, om, os, self.output_clip),
                )
            }
        };
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, self.clock_s, prompt_len, output_len)
    }

    /// Generate a trace covering `duration_s` seconds.
    pub fn trace(&mut self, duration_s: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let r = self.next_request();
            if r.arrival_s > duration_s {
                break;
            }
            out.push(r);
        }
        out
    }

    /// Generate exactly `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Attach random prompt token ids (for the real CPU path).
    pub fn with_tokens(&mut self, mut reqs: Vec<Request>, vocab: u32) -> Vec<Request> {
        for r in &mut reqs {
            r.prompt_tokens = (0..r.prompt_len)
                .map(|_| self.rng.range_u64(0, vocab as u64) as u32)
                .collect();
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn median(mut v: Vec<usize>) -> usize {
        v.sort_unstable();
        v[v.len() / 2]
    }

    #[test]
    fn deterministic_given_seed() {
        let a = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 42).take(50);
        let b = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 42).take(50);
        assert_eq!(a, b);
        let c = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 43).take(50);
        assert_ne!(a, c);
    }

    #[test]
    fn arrivals_strictly_increase_at_mean_rate() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 4.0, 1).take(2000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        let span = reqs.last().unwrap().arrival_s;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 4.0).abs() / 4.0 < 0.1, "empirical rate {rate}");
    }

    #[test]
    fn sharegpt_length_statistics() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 1.0, 7).take(4000);
        let pm = median(reqs.iter().map(|r| r.prompt_len).collect());
        let om = median(reqs.iter().map(|r| r.output_len).collect());
        assert!((150..300).contains(&pm), "prompt median {pm}");
        assert!((120..260).contains(&om), "output median {om}");
    }

    #[test]
    fn openthoughts_output_dominates_prompt() {
        let reqs = TraceGenerator::new(WorkloadKind::OpenThoughts, 1.0, 7).take(4000);
        let pm = median(reqs.iter().map(|r| r.prompt_len).collect()) as f64;
        let om = median(reqs.iter().map(|r| r.output_len).collect()) as f64;
        assert!(om / pm > 5.0, "output/prompt ratio = {}", om / pm);
    }

    #[test]
    fn clip_respected() {
        let reqs = TraceGenerator::new(WorkloadKind::OpenThoughts, 1.0, 3)
            .with_clip((4, 48), (1, 64))
            .take(500);
        for r in &reqs {
            assert!((4..=48).contains(&r.prompt_len));
            assert!((1..=64).contains(&r.output_len));
        }
    }

    #[test]
    fn fixed_kind_is_fixed() {
        let reqs =
            TraceGenerator::new(WorkloadKind::Fixed { prompt: 32, output: 16 }, 1.0, 0).take(10);
        assert!(reqs.iter().all(|r| r.prompt_len == 32 && r.output_len == 16));
    }

    #[test]
    fn trace_bounded_by_duration() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 10.0, 5).trace(3.0);
        assert!(!reqs.is_empty());
        assert!(reqs.iter().all(|r| r.arrival_s <= 3.0));
    }

    #[test]
    fn poisson_default_matches_legacy_sampling_exactly() {
        // The pre-pattern generator drew one exp(rate) gap then the two
        // log-normal lengths per request. The Poisson path must consume
        // the RNG in exactly that order (bit-identical seeded traces).
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 42)
            .with_arrivals(ArrivalPattern::Poisson)
            .take(100);
        let mut rng = crate::util::rng::Rng::seed_from_u64(42);
        let mut clock = 0.0f64;
        for (i, r) in reqs.iter().enumerate() {
            clock += rng.exp(2.0);
            let p = (rng.lognormal(220f64.ln(), 0.95).round() as usize).clamp(4, 8192);
            let o = (rng.lognormal(180f64.ln(), 0.85).round() as usize).clamp(1, 8192);
            assert_eq!(r.arrival_s.to_bits(), clock.to_bits(), "req {i} arrival");
            assert_eq!((r.prompt_len, r.output_len), (p, o), "req {i} lengths");
        }
    }

    #[test]
    fn bursty_concentrates_arrivals_in_burst_windows() {
        let pattern = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 8.0, 11)
            .with_arrivals(pattern)
            .trace(600.0);
        let (mut in_burst, mut in_trough) = (0usize, 0usize);
        for r in &reqs {
            if r.arrival_s % 30.0 < 7.5 {
                in_burst += 1;
            } else {
                in_trough += 1;
            }
        }
        // Burst windows are 1/4 of the time at 3x rate; troughs carry the
        // compensating 1/3x rate. Empirical per-second ratio ~9.
        let burst_rate = in_burst as f64 / (600.0 * 0.25);
        let trough_rate = in_trough as f64 / (600.0 * 0.75);
        assert!(
            burst_rate / trough_rate > 4.0,
            "burst {burst_rate:.2}/s vs trough {trough_rate:.2}/s"
        );
        // Mean offered load is preserved.
        let mean = reqs.len() as f64 / 600.0;
        assert!((mean - 8.0).abs() / 8.0 < 0.15, "mean rate {mean:.2}");
        // Strictly increasing arrivals survive the segment restarts.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn diurnal_modulates_rate_with_the_sinusoid() {
        let pattern = ArrivalPattern::Diurnal { period_s: 100.0, depth: 0.8 };
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 6.0, 9)
            .with_arrivals(pattern)
            .trace(1000.0);
        // sin > 0 on the first half of each period: that half must carry
        // visibly more arrivals than the second.
        let (mut up, mut down) = (0usize, 0usize);
        for r in &reqs {
            if r.arrival_s % 100.0 < 50.0 {
                up += 1;
            } else {
                down += 1;
            }
        }
        assert!(up as f64 > down as f64 * 1.5, "up {up} down {down}");
        let mean = reqs.len() as f64 / 1000.0;
        assert!((mean - 6.0).abs() / 6.0 < 0.15, "mean rate {mean:.2}");
    }

    #[test]
    fn patterned_traces_are_seed_deterministic() {
        let pattern = ArrivalPattern::Bursty { period_s: 20.0, duty: 0.3, mult: 2.5 };
        let a = TraceGenerator::new(WorkloadKind::ShareGpt, 4.0, 5)
            .with_arrivals(pattern)
            .take(200);
        let b = TraceGenerator::new(WorkloadKind::ShareGpt, 4.0, 5)
            .with_arrivals(pattern)
            .take(200);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "duty*mult")]
    fn bursty_with_no_trough_rate_rejected() {
        let _ = TraceGenerator::new(WorkloadKind::ShareGpt, 4.0, 5)
            .with_arrivals(ArrivalPattern::Bursty { period_s: 10.0, duty: 0.5, mult: 2.0 });
    }

    #[test]
    fn with_tokens_populates_prompt_ids() {
        let mut g = TraceGenerator::new(WorkloadKind::Fixed { prompt: 8, output: 4 }, 1.0, 0);
        let reqs = g.take(3);
        let reqs = g.with_tokens(reqs, 256);
        for r in &reqs {
            assert_eq!(r.prompt_tokens.len(), 8);
            assert!(r.prompt_tokens.iter().all(|&t| t < 256));
        }
    }
}
