//! Request descriptor shared by the simulator and the real serving path.

pub type RequestId = u64;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length, tokens.
    pub prompt_len: usize,
    /// Output tokens to generate (the trace's ground-truth output length;
    /// serving systems see it as max_tokens).
    pub output_len: usize,
    /// Concrete prompt token ids — only populated for the real CPU serving
    /// path (the simulator works from lengths alone).
    pub prompt_tokens: Vec<u32>,
}

impl Request {
    pub fn new(id: RequestId, arrival_s: f64, prompt_len: usize, output_len: usize) -> Self {
        Request { id, arrival_s, prompt_len, output_len, prompt_tokens: Vec::new() }
    }

    /// Total KV tokens this request holds at completion.
    pub fn total_tokens(&self) -> usize {
        self.prompt_len + self.output_len
    }

    /// `max_token` in Algorithm 1: the sequence-length budget the scheduler
    /// reserves when admitting this request's attention for offload.
    pub fn max_token(&self) -> usize {
        self.total_tokens()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let r = Request::new(1, 0.5, 100, 50);
        assert_eq!(r.total_tokens(), 150);
        assert_eq!(r.max_token(), 150);
    }

    #[test]
    fn construction_defaults() {
        let r = Request::new(2, 1.0, 10, 5);
        assert!(r.prompt_tokens.is_empty());
        assert_eq!(r.id, 2);
    }
}
