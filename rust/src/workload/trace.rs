//! Replayable trace files: serialize generated traces so experiments are
//! exactly reproducible across machines and runs (and so real request logs
//! can be replayed through both the simulator and the CPU serving path).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;
use crate::Result;

use super::request::Request;

/// Serialize a trace to JSON.
pub fn trace_to_json(requests: &[Request]) -> String {
    Json::Arr(
        requests
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("id".into(), Json::Num(r.id as f64));
                o.insert("arrival_s".into(), Json::Num(r.arrival_s));
                o.insert("prompt_len".into(), Json::Num(r.prompt_len as f64));
                o.insert("output_len".into(), Json::Num(r.output_len as f64));
                if !r.prompt_tokens.is_empty() {
                    o.insert(
                        "prompt_tokens".into(),
                        Json::Arr(r.prompt_tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                    );
                }
                Json::Obj(o)
            })
            .collect(),
    )
    .to_string()
}

/// Parse a trace from JSON.
pub fn trace_from_json(text: &str) -> Result<Vec<Request>> {
    let v = Json::parse(text)?;
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("trace must be a JSON array"))?;
    let mut out = Vec::with_capacity(arr.len());
    let mut last_arrival = f64::NEG_INFINITY;
    for (i, e) in arr.iter().enumerate() {
        let field = |k: &str| {
            e.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("trace[{i}]: missing numeric `{k}`"))
        };
        let mut r = Request::new(
            field("id")? as u64,
            field("arrival_s")?,
            field("prompt_len")? as usize,
            field("output_len")? as usize,
        );
        anyhow::ensure!(r.prompt_len >= 1, "trace[{i}]: empty prompt");
        anyhow::ensure!(r.output_len >= 1, "trace[{i}]: empty output");
        anyhow::ensure!(
            r.arrival_s >= last_arrival,
            "trace[{i}]: arrivals must be non-decreasing"
        );
        last_arrival = r.arrival_s;
        if let Some(toks) = e.get("prompt_tokens").and_then(Json::as_arr) {
            r.prompt_tokens = toks
                .iter()
                .map(|t| {
                    t.as_u64()
                        .map(|n| n as u32)
                        .ok_or_else(|| anyhow::anyhow!("trace[{i}]: bad token"))
                })
                .collect::<Result<_>>()?;
            anyhow::ensure!(
                r.prompt_tokens.len() == r.prompt_len,
                "trace[{i}]: prompt_tokens/prompt_len mismatch"
            );
        }
        out.push(r);
    }
    Ok(out)
}

pub fn save_trace(path: &Path, requests: &[Request]) -> Result<()> {
    std::fs::write(path, trace_to_json(requests))?;
    Ok(())
}

pub fn load_trace(path: &Path) -> Result<Vec<Request>> {
    trace_from_json(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{TraceGenerator, WorkloadKind};

    #[test]
    fn roundtrip_without_tokens() {
        let reqs = TraceGenerator::new(WorkloadKind::ShareGpt, 2.0, 9).take(25);
        let back = trace_from_json(&trace_to_json(&reqs)).unwrap();
        assert_eq!(reqs, back);
    }

    #[test]
    fn roundtrip_with_tokens() {
        let mut g = TraceGenerator::new(WorkloadKind::Fixed { prompt: 6, output: 3 }, 1.0, 2);
        let reqs = g.take(4);
        let reqs = g.with_tokens(reqs, 256);
        let back = trace_from_json(&trace_to_json(&reqs)).unwrap();
        assert_eq!(reqs, back);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("adrenaline_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let reqs = TraceGenerator::new(WorkloadKind::OpenThoughts, 1.0, 5).take(10);
        save_trace(&path, &reqs).unwrap();
        assert_eq!(load_trace(&path).unwrap(), reqs);
    }

    #[test]
    fn rejects_malformed() {
        assert!(trace_from_json("{}").is_err());
        assert!(trace_from_json(r#"[{"id": 0}]"#).is_err());
        // Decreasing arrivals.
        let bad = r#"[
            {"id": 0, "arrival_s": 5.0, "prompt_len": 4, "output_len": 2},
            {"id": 1, "arrival_s": 1.0, "prompt_len": 4, "output_len": 2}
        ]"#;
        assert!(trace_from_json(bad).is_err());
        // Token/length mismatch.
        let bad2 = r#"[{"id": 0, "arrival_s": 0.0, "prompt_len": 3,
                        "output_len": 1, "prompt_tokens": [1, 2]}]"#;
        assert!(trace_from_json(bad2).is_err());
    }
}
