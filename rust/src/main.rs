//! `adrenaline` — leader entrypoint and CLI.
//!
//! Subcommands (argument parsing is hand-rolled; the offline vendor set
//! has no clap):
//!
//!   serve     Run the REAL serving path: tiny Llama over PJRT CPU with
//!             the full proxy / prefill+executor / decode topology.
//!   simulate  One A100-scale cluster simulation; prints the SimReport.
//!   bounds    Print the offload bounds (Eqs 1–3) for a model/SLO.
//!   figures   Hint: use the dedicated `figures` binary.
//!
//! Examples:
//!   adrenaline serve --requests 12 --offload load_aware
//!   adrenaline simulate --model 7b --workload sharegpt --rate 24 \
//!       --duration 120 --offload disabled
//!   adrenaline bounds --model 13b --avg-seq 1024

use adrenaline::config::{ClusterSpec, ModelSpec, OffloadPolicy, ServingConfig, SloConfig};
use adrenaline::coordinator::OffloadBounds;
use adrenaline::engine::Server;
use adrenaline::runtime::Manifest;
use adrenaline::sim::{ClusterSim, SimConfig};
use adrenaline::workload::{TraceGenerator, WorkloadKind};

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                    continue;
                }
            }
            i += 1;
        }
        Args { flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn model(&self) -> ModelSpec {
        match self.get("model").unwrap_or("7b") {
            "13b" => ModelSpec::llama2_13b(),
            "tiny" => ModelSpec::tiny(),
            _ => ModelSpec::llama2_7b(),
        }
    }

    fn workload(&self) -> WorkloadKind {
        match self.get("workload").unwrap_or("sharegpt") {
            "openthoughts" => WorkloadKind::OpenThoughts,
            _ => WorkloadKind::ShareGpt,
        }
    }

    fn offload(&self) -> OffloadPolicy {
        match self.get("offload").unwrap_or("load_aware") {
            "disabled" => OffloadPolicy::Disabled,
            "load_aware" => OffloadPolicy::LoadAware,
            "load_aware_strict" => OffloadPolicy::LoadAwareStrict,
            r => OffloadPolicy::FixedRatio(r.parse().unwrap_or(0.7)),
        }
    }
}

fn main() -> adrenaline::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "serve" => serve(&args),
        "simulate" => simulate(&args),
        "bounds" => bounds(&args),
        "figures" => {
            println!("use the dedicated binary: cargo run --release --bin figures [fig..|all]");
            Ok(())
        }
        _ => {
            println!(
                "adrenaline — attention disaggregation for PD-disaggregated LLM serving\n\
                 \n\
                 USAGE: adrenaline <serve|simulate|bounds> [--key value ...]\n\
                 \n\
                 serve     --requests N --offload <disabled|load_aware|RATIO> --seed S\n\
                 simulate  --model <7b|13b> --workload <sharegpt|openthoughts>\n\
                 \x20          --rate R --duration D --offload <...> --seed S\n\
                 \x20          [--prefill-instances N] [--adaptive-partition 1]\n\
                 \x20          [--save-trace FILE]\n\
                 bounds    --model <7b|13b> --avg-seq TOKENS --tpot-slo S"
            );
            Ok(())
        }
    }
}

/// The real CPU-PJRT serving path on the tiny model.
fn serve(args: &Args) -> adrenaline::Result<()> {
    let n = args.usize("requests", 8);
    let seed = args.f64("seed", 7.0) as u64;
    let cfg = ServingConfig { offload: args.offload(), ..Default::default() };

    println!("loading artifacts from {} ...", Manifest::default_dir().display());
    let mut server = Server::start(&Manifest::default_dir(), cfg)?;

    let mut gen = TraceGenerator::new(WorkloadKind::ShareGpt, 4.0, seed).with_clip((4, 48), (1, 48));
    let reqs = gen.take(n);
    let reqs = gen.with_tokens(reqs, 256);
    println!("serving {n} requests ...");
    let report = server.run_requests(&reqs, None)?;

    for c in &report.completions {
        println!(
            "request {:>3}  offloaded={:<5}  {} tokens: {:?}",
            c.id,
            c.offloaded,
            c.tokens.len(),
            &c.tokens[..c.tokens.len().min(8)]
        );
    }
    let ttft = report.metrics.ttft_stats();
    let tpot = report.metrics.tpot_stats();
    println!(
        "\nserved {} requests in {:.2}s  ({} offloaded, {} decode steps, {} fused)",
        report.completions.len(),
        report.wall_s,
        report.offloaded_requests,
        report.decode_steps,
        report.fused_steps
    );
    if let (Some(t1), Some(t2)) = (ttft, tpot) {
        println!(
            "TTFT mean {:.1} ms   TPOT mean {:.1} ms p99 {:.1} ms   throughput {:.1} tok/s",
            t1.mean * 1e3,
            t2.mean * 1e3,
            t2.p99 * 1e3,
            report.metrics.total_output_tokens() as f64 / report.wall_s
        );
    }
    Ok(())
}

/// One A100-scale simulation run.
fn simulate(args: &Args) -> adrenaline::Result<()> {
    let mut cfg = SimConfig::paper_default(args.model(), args.workload(), args.f64("rate", 24.0));
    cfg.duration_s = args.f64("duration", 120.0);
    cfg.seed = args.f64("seed", 42.0) as u64;
    cfg.serving.offload = args.offload();
    cfg.cluster.n_prefill = args.usize("prefill-instances", 1) as u32;
    cfg.cluster.n_decode = args.usize("decode-instances", 1) as u32;
    if args.get("adaptive-partition").is_some() {
        cfg = cfg.with_adaptive_partition(args.f64("avg-prompt", 512.0) as u64);
        println!("adaptive partition: executor SM share = {:.2}", cfg.cluster.attn_executor_sm_frac);
    }
    if let Some(path) = args.get("save-trace") {
        use adrenaline::workload::{save_trace, TraceGenerator};
        let mut g = TraceGenerator::new(cfg.workload, cfg.rate, cfg.seed);
        let reqs = g.trace(cfg.duration_s);
        save_trace(std::path::Path::new(path), &reqs)?;
        println!("saved {} requests to {path}", reqs.len());
    }
    let r = ClusterSim::new(cfg).run();
    println!("arrived            {}", r.arrived);
    println!("finished           {}", r.finished);
    println!("preemptions        {}", r.preemptions);
    println!("offloaded fraction {:.3}", r.offloaded_fraction);
    if let Some(t) = r.ttft {
        println!("TTFT  mean {:.3} s  p99 {:.3} s", t.mean, t.p99);
    }
    if let Some(t) = r.tpot {
        println!("TPOT  mean {:.4} s  p99 {:.4} s", t.mean, t.p99);
    }
    println!("throughput         {:.1} tok/s (stable window)", r.throughput);
    println!("prefill HBM cap    {:.3}", r.prefill_hbm_capacity_util);
    println!("prefill HBM bw     {:.3}", r.prefill_hbm_bw_util);
    println!("decode compute     {:.3}", r.decode_compute_util);
    println!("executor duty      {:.3}", r.executor_duty);
    Ok(())
}

/// Print the computed offload bounds (Eqs 1–3).
fn bounds(args: &Args) -> adrenaline::Result<()> {
    let slo = SloConfig { tpot_s: args.f64("tpot-slo", 0.1), ttft_s: args.f64("ttft-slo", 1.0) };
    let b = OffloadBounds::compute(
        &ClusterSpec::paper_default(),
        &args.model(),
        &slo,
        args.f64("avg-seq", 1024.0) as u64,
    );
    println!("OB_mem  = {:.3}   (Eq 1)", b.ob_mem);
    println!("B_max   = {}", b.b_max);
    println!("B_TPOT  = {}", b.b_tpot);
    println!("OB_comp = {:.3}   (Eq 2)", b.ob_comp());
    println!("OB      = {:.3}   (Eq 3)", b.ob());
    Ok(())
}
