//! The attention executor — the paper's core new component (§3.1, Fig 7):
//! a service colocated with the prefill engine that stores offloaded
//! requests' KV caches in the prefill instance's spare HBM and executes
//! their decode-phase attention.
//!
//! Two layers:
//!
//! * [`AttentionExecutor`] — the synchronous core: offload KV pool
//!   ([`KvSlab`]), per-request metadata, and `execute()` which appends the
//!   step's k/v rows and runs the attention artifact. Reusable by both the
//!   threaded server and unit tests.
//! * [`ExecutorHandle`] / [`run_prefill_instance`] — the threaded wrapper:
//!   one OS thread owns the prefill instance's [`ModelRuntime`] (= its
//!   GPU) and serves both prefill jobs and attention offload steps over
//!   channels, draining attention work first (it sits on the decode
//!   critical path; prefill tolerates queueing — the scheduling-priority
//!   analogue of the paper's MPS partition).
//!
//! §3.2.1 optimizations carried over:
//! ① metadata/KV management happens on `Hint`/`AdmitKv`/`Release`
//!   messages, outside the per-layer critical path;
//! ② the per-step message carries one packed qkv buffer, not three
//!   scattered tensors;
//! ③ the decode engine sends the request *before* running its local
//!   attention, overlapping the two (see decode.rs).

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use crate::kv::slab::{KvShape, KvSlab};
use crate::kv::SeqId;
use crate::runtime::ModelRuntime;
use crate::Result;

use super::prefill::{PrefillEngine, PrefillResult};

/// One offloaded attention step for a sub-batch (aggregated qkv, §3.2.1 ②).
#[derive(Debug, Clone)]
pub struct AttnRequest {
    pub layer: usize,
    /// Offloaded sequence ids, in batch-row order.
    pub ids: Vec<SeqId>,
    /// Packed `[n_rows, 3, H*D]`: q, k_new, v_new per row.
    pub qkv: Vec<f32>,
    /// Write position of this step's token per row.
    pub positions: Vec<i32>,
    /// Attention bucket (C_o) selected by the decode-side graph cache.
    pub bucket: usize,
}

/// The executor's reply for one layer step.
#[derive(Debug, Clone)]
pub struct AttnResponse {
    pub layer: usize,
    /// `[bucket, D]` attention output (rows beyond n_rows are padding).
    pub attn_out: Vec<f32>,
    /// GPU-side execution time, seconds (for the §Perf breakdown).
    pub exec_s: f64,
}

/// Synchronous attention-executor core.
pub struct AttentionExecutor {
    kv: KvSlab,
    /// Request metadata initialized by `hint` (①).
    meta: HashMap<SeqId, usize>, // id -> prompt_len
    // Reused scratch for gathered caches (avoids per-step allocation).
    k_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
    /// Steps executed (observability).
    pub steps: u64,
    /// Total rows (request-layer attention computations) executed.
    pub rows: u64,
}

impl AttentionExecutor {
    pub fn new(shape: KvShape) -> Self {
        AttentionExecutor {
            kv: KvSlab::new(shape),
            meta: HashMap::new(),
            k_scratch: Vec::new(),
            v_scratch: Vec::new(),
            steps: 0,
            rows: 0,
        }
    }

    /// Number of offloaded sequences resident.
    pub fn resident(&self) -> usize {
        self.kv.len()
    }

    /// ① Pre-register an offloaded request before its KV arrives.
    pub fn hint(&mut self, id: SeqId, prompt_len: usize) {
        self.meta.insert(id, prompt_len);
    }

    /// Install an offloaded request's prefill KV (colocated: the prefill
    /// output never leaves the instance).
    pub fn admit_kv(
        &mut self,
        id: SeqId,
        k: &[f32],
        v: &[f32],
        bucket_seq: usize,
        tokens: usize,
    ) {
        self.kv.insert_from_prefill(id, k, v, bucket_seq, tokens);
        self.meta.entry(id).or_insert(tokens);
    }

    pub fn release(&mut self, id: SeqId) {
        self.kv.remove(id);
        self.meta.remove(&id);
    }

    /// Execute one offloaded attention step on the shared runtime.
    pub fn execute(&mut self, runtime: &mut ModelRuntime, req: &AttnRequest) -> Result<AttnResponse> {
        let t0 = Instant::now();
        let n = req.ids.len();
        anyhow::ensure!(n > 0 && n <= req.bucket, "bad sub-batch: {n} rows, bucket {}", req.bucket);
        let hd = runtime.n_heads() * runtime.head_dim();
        anyhow::ensure!(req.qkv.len() == n * 3 * hd, "packed qkv size mismatch");

        // Append this step's k/v rows, then gather bucket-sized caches.
        for (row, &id) in req.ids.iter().enumerate() {
            let base = row * 3 * hd;
            let k_row = &req.qkv[base + hd..base + 2 * hd];
            let v_row = &req.qkv[base + 2 * hd..base + 3 * hd];
            self.kv.write_token(id, req.layer, req.positions[row] as usize, k_row, v_row);
        }
        let plane = runtime.kv_plane();
        // No per-step zeroing: stale bytes beyond each row's seq_len are
        // masked inside the kernel (see decode.rs §Perf note).
        if self.k_scratch.len() != req.bucket * plane {
            self.k_scratch.resize(req.bucket * plane, 0.0);
            self.v_scratch.resize(req.bucket * plane, 0.0);
        }
        self.kv.gather_layer(
            &req.ids,
            req.layer,
            &mut self.k_scratch[..n * plane],
            &mut self.v_scratch[..n * plane],
        );

        // q padded to the bucket; seq_lens padded with 1 (kernel needs >=1).
        let mut q = vec![0.0f32; req.bucket * hd];
        let mut seq_lens = vec![1i32; req.bucket];
        for row in 0..n {
            q[row * hd..(row + 1) * hd]
                .copy_from_slice(&req.qkv[row * 3 * hd..row * 3 * hd + hd]);
            seq_lens[row] = req.positions[row] + 1;
        }

        let attn_out =
            runtime.attention(&q, &self.k_scratch, &self.v_scratch, &seq_lens, req.bucket)?;
        self.steps += 1;
        self.rows += n as u64;
        Ok(AttnResponse { layer: req.layer, attn_out, exec_s: t0.elapsed().as_secs_f64() })
    }
}

/// Messages into the prefill-instance thread.
pub enum ExecutorMsg {
    /// Run a prefill (reply carries the result; offloaded requests' KV is
    /// then installed via `AdmitKv` without leaving the instance).
    Prefill { id: SeqId, prompt: Vec<i32>, reply: Sender<Result<PrefillResult>> },
    /// ① Early metadata registration for an offloaded request.
    Hint { id: SeqId, prompt_len: usize },
    /// Install offloaded KV from a prefill result.
    AdmitKv { id: SeqId, k: Vec<f32>, v: Vec<f32>, bucket_seq: usize, tokens: usize },
    /// One offloaded attention layer step (critical path).
    Attn(AttnRequest),
    /// Request finished: free its offload KV.
    Release { id: SeqId },
    Shutdown,
}

/// Decode-side handle to the prefill instance thread.
pub struct ExecutorHandle {
    pub tx: Sender<ExecutorMsg>,
    /// Attention responses come back on a dedicated channel so the decode
    /// engine can block on exactly the message it needs.
    pub attn_rx: Receiver<AttnResponse>,
}

/// Body of the prefill-instance thread: loads and owns the instance's
/// runtime (PJRT clients are not `Send`, and a real instance would load
/// its own model anyway) and serves prefill + offloaded attention,
/// attention first. Sends one readiness message after warmup.
pub fn run_prefill_instance(
    artifact_dir: std::path::PathBuf,
    rx: Receiver<ExecutorMsg>,
    attn_tx: Sender<AttnResponse>,
    ready_tx: Sender<Result<()>>,
) -> Result<()> {
    let mut runtime = match ModelRuntime::load(&artifact_dir).and_then(|mut rt| {
        rt.warmup()?;
        Ok(rt)
    }) {
        Ok(rt) => {
            let _ = ready_tx.send(Ok(()));
            rt
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready_tx.send(Err(e));
            anyhow::bail!("prefill instance failed to start: {msg}");
        }
    };
    let shape = KvShape {
        n_layers: runtime.n_layers(),
        max_seq: runtime.max_seq_len(),
        n_heads: runtime.n_heads(),
        head_dim: runtime.head_dim(),
    };
    let mut executor = AttentionExecutor::new(shape);
    let mut prefill = PrefillEngine::new();
    // Local FIFO of deferred (non-attention) work: attention drains first.
    let mut deferred: std::collections::VecDeque<ExecutorMsg> = Default::default();

    'outer: loop {
        // Pull everything currently queued, partitioning by cost class:
        //
        // * control messages (Hint / AdmitKv / Release) are cheap metadata
        //   and KV-pool updates — applied IMMEDIATELY, in arrival order.
        //   This is also an ordering requirement, not just a priority: an
        //   Attn step for a sequence must never run before that sequence's
        //   AdmitKv (the sender emits AdmitKv strictly first, so draining
        //   control before attention preserves the dependency);
        // * attention steps sit on the decode critical path — run next;
        // * prefills are long — at most one per cycle, so queued attention
        //   never waits behind a prefill backlog (the scheduling analogue
        //   of the paper's MPS partition).
        let mut attn_batch: Vec<AttnRequest> = Vec::new();
        let first = if let Some(m) = deferred.pop_front() {
            m
        } else {
            match rx.recv() {
                Ok(m) => m,
                Err(_) => break 'outer, // all senders gone
            }
        };
        let mut pending = vec![first];
        while let Ok(m) = rx.try_recv() {
            pending.push(m);
        }
        for msg in pending {
            match msg {
                ExecutorMsg::Attn(req) => attn_batch.push(req),
                ExecutorMsg::Hint { id, prompt_len } => executor.hint(id, prompt_len),
                ExecutorMsg::AdmitKv { id, k, v, bucket_seq, tokens } => {
                    executor.admit_kv(id, &k, &v, bucket_seq, tokens)
                }
                ExecutorMsg::Release { id } => executor.release(id),
                ExecutorMsg::Shutdown => break 'outer,
                prefill_msg @ ExecutorMsg::Prefill { .. } => deferred.push_back(prefill_msg),
            }
        }

        // 1) Attention steps (decode critical path).
        for req in attn_batch {
            let resp = executor.execute(&mut runtime, &req)?;
            if attn_tx.send(resp).is_err() {
                break 'outer;
            }
        }
        // 2) One deferred prefill per cycle.
        if let Some(ExecutorMsg::Prefill { id, prompt, reply }) = deferred.pop_front() {
            let result = prefill.run(&mut runtime, id, &prompt);
            let _ = reply.send(result);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> KvShape {
        KvShape { n_layers: 2, max_seq: 16, n_heads: 2, head_dim: 4 }
    }

    #[test]
    fn hint_then_admit_then_release_lifecycle() {
        let mut ex = AttentionExecutor::new(shape());
        ex.hint(7, 5);
        assert_eq!(ex.resident(), 0, "hint alone stores no KV");
        let plane = 16 * 8;
        ex.admit_kv(7, &vec![0.5; 2 * plane], &vec![0.5; 2 * plane], 16, 5);
        assert_eq!(ex.resident(), 1);
        ex.release(7);
        assert_eq!(ex.resident(), 0);
    }

    #[test]
    fn execute_validates_inputs() {
        // No runtime needed: validation fails before any PJRT call… but
        // execute takes a runtime, so this test only checks the cheap
        // validations through a deliberately-bad request to a panicking
        // stub. Covered fully in rust/tests/ integration (needs artifacts).
        let req = AttnRequest {
            layer: 0,
            ids: vec![],
            qkv: vec![],
            positions: vec![],
            bucket: 4,
        };
        assert!(req.ids.is_empty()); // structure sanity
    }
}
