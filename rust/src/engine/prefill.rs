//! Prefill engine: run a prompt through the fused prefill artifact and
//! hand the populated KV cache to its consumer — the decode instance for
//! local requests, or (zero-copy, colocated) the attention executor for
//! offloaded ones.

use std::time::Instant;

use crate::runtime::ModelRuntime;
use crate::workload::RequestId;
use crate::Result;

/// Output of one prefill execution.
#[derive(Debug, Clone)]
pub struct PrefillResult {
    pub id: RequestId,
    pub first_token: i32,
    /// `[L, P_bucket, H, D]` flattened.
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    /// Prompt bucket used (leading seq dim of the caches).
    pub bucket: usize,
    /// Valid prompt tokens within the bucket.
    pub prompt_len: usize,
    /// Prefill execution wall time, seconds.
    pub latency_s: f64,
}

/// Stateless executor for prefill steps (the state — the PJRT client and
/// compiled artifacts — lives in the shared [`ModelRuntime`]).
#[derive(Debug, Default)]
pub struct PrefillEngine {
    /// Prompts processed (observability).
    pub completed: u64,
    /// Total prompt tokens processed.
    pub total_tokens: u64,
}

impl PrefillEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run one prompt. `runtime` is the prefill instance's runtime (shared
    /// with the colocated attention executor).
    pub fn run(
        &mut self,
        runtime: &mut ModelRuntime,
        id: RequestId,
        prompt: &[i32],
    ) -> Result<PrefillResult> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt for request {id}");
        anyhow::ensure!(
            prompt.len() <= runtime.max_seq_len(),
            "prompt of {} exceeds max_seq_len {}",
            prompt.len(),
            runtime.max_seq_len()
        );
        let t0 = Instant::now();
        let out = runtime.prefill(prompt)?;
        self.completed += 1;
        self.total_tokens += prompt.len() as u64;
        Ok(PrefillResult {
            id,
            first_token: out.first_token,
            k_cache: out.k_cache,
            v_cache: out.v_cache,
            bucket: out.bucket,
            prompt_len: prompt.len(),
            latency_s: t0.elapsed().as_secs_f64(),
        })
    }
}
