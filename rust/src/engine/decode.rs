//! Decode engine: continuous-batched decode steps with per-layer
//! attention disaggregation (§3.2, Fig 8b).
//!
//! Per step, the batch is partitioned into *local* rows (KV resident here)
//! and *offloaded* rows (KV resident in the attention executor on the
//! prefill instance). The layer loop then:
//!
//! 1. runs `layer_pre` (RMSNorm + QKV + RoPE) for the whole batch;
//! 2. **sends** the offloaded rows' packed qkv to the executor (one
//!    aggregated message, §3.2.1 ②) — *before* doing local work, so the
//!    remote attention overlaps the local attention (③);
//! 3. appends local rows' k/v to the local KV slab and runs the local
//!    attention kernel;
//! 4. receives the remote output, merges the two by row, and runs
//!    `layer_post`.
//!
//! When nothing in the batch is offloaded the engine takes the fused
//! decode artifact instead (one PJRT call for the whole step) — the
//! no-offload fast path and ablation baseline (DESIGN.md §6.1).

use std::collections::HashMap;
use std::time::Instant;

use crate::coordinator::GraphCache;
use crate::kv::slab::{KvShape, KvSlab};
use crate::kv::SeqId;
use crate::runtime::ModelRuntime;
use crate::Result;

use super::attention_executor::{AttnRequest, ExecutorHandle, ExecutorMsg};

/// Per-sequence decode state.
#[derive(Debug, Clone, Copy)]
pub struct SeqState {
    /// Last emitted token (input to the next step).
    pub token: i32,
    /// Position the next token's KV will occupy (= current length).
    pub position: usize,
    /// Attention offloaded to the prefill instance?
    pub offloaded: bool,
}

/// Outcome of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeOutcome {
    /// (sequence, next token) in the step's row order.
    pub tokens: Vec<(SeqId, i32)>,
    pub step_s: f64,
    /// Local attention kernel time within the step.
    pub local_attn_s: f64,
    /// Time spent blocked on the executor *after* local work finished —
    /// the synchronization stall the paper's overlap minimizes.
    pub remote_stall_s: f64,
    pub used_fused: bool,
}

/// Aggregate decode statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecodeStats {
    pub steps: u64,
    pub fused_steps: u64,
    pub offloaded_row_steps: u64,
    pub local_row_steps: u64,
    pub total_stall_s: f64,
}

/// The decode instance.
pub struct DecodeEngine {
    pub runtime: ModelRuntime,
    kv: KvSlab,
    graph: GraphCache,
    seqs: HashMap<SeqId, SeqState>,
    pub stats: DecodeStats,
    /// Take the fused artifact when no row is offloaded (default on).
    pub use_fused_fast_path: bool,
    // Reused scratch (hot path stays allocation-free after warmup).
    k_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
}

impl DecodeEngine {
    pub fn new(runtime: ModelRuntime, graph: GraphCache) -> Self {
        let shape = KvShape {
            n_layers: runtime.n_layers(),
            max_seq: runtime.max_seq_len(),
            n_heads: runtime.n_heads(),
            head_dim: runtime.head_dim(),
        };
        DecodeEngine {
            runtime,
            kv: KvSlab::new(shape),
            graph,
            seqs: HashMap::new(),
            stats: DecodeStats::default(),
            use_fused_fast_path: true,
            k_scratch: Vec::new(),
            v_scratch: Vec::new(),
        }
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn seq(&self, id: SeqId) -> Option<&SeqState> {
        self.seqs.get(&id)
    }

    pub fn graph_cache(&self) -> &GraphCache {
        &self.graph
    }

    /// Admit a local request: install its prefill KV here.
    pub fn admit_local(
        &mut self,
        id: SeqId,
        first_token: i32,
        prompt_len: usize,
        k: &[f32],
        v: &[f32],
        bucket_seq: usize,
    ) {
        self.kv.insert_from_prefill(id, k, v, bucket_seq, prompt_len);
        self.seqs.insert(id, SeqState { token: first_token, position: prompt_len, offloaded: false });
    }

    /// Admit an offloaded request: only control state lives here; the KV
    /// stays with the attention executor (it never crossed instances).
    pub fn admit_offloaded(&mut self, id: SeqId, first_token: i32, prompt_len: usize) {
        self.seqs.insert(id, SeqState { token: first_token, position: prompt_len, offloaded: true });
    }

    /// Drop a finished/preempted request. Returns whether it was offloaded
    /// (caller must then `Release` it at the executor).
    pub fn release(&mut self, id: SeqId) -> Option<bool> {
        let state = self.seqs.remove(&id)?;
        if !state.offloaded {
            self.kv.remove(id);
        }
        Some(state.offloaded)
    }

    /// Sequences that can still grow (position < max_seq_len).
    pub fn runnable(&self) -> Vec<SeqId> {
        let max = self.runtime.max_seq_len();
        let mut ids: Vec<SeqId> =
            self.seqs.iter().filter(|(_, s)| s.position < max).map(|(&id, _)| id).collect();
        ids.sort_unstable();
        ids
    }

    /// Run one decode step over `ids`. `executor` must be `Some` whenever
    /// any of the rows is offloaded.
    pub fn step(
        &mut self,
        ids: &[SeqId],
        executor: Option<&ExecutorHandle>,
    ) -> Result<DecodeOutcome> {
        anyhow::ensure!(!ids.is_empty(), "empty decode step");
        let t0 = Instant::now();

        // Partition: local rows first, then offloaded (fixed row order).
        let mut rows: Vec<SeqId> = Vec::with_capacity(ids.len());
        let mut n_local = 0usize;
        for &id in ids {
            let s = self.seqs.get(&id).ok_or_else(|| anyhow::anyhow!("unknown seq {id}"))?;
            anyhow::ensure!(
                s.position < self.runtime.max_seq_len(),
                "seq {id} is at max_seq_len; must be retired"
            );
            if !s.offloaded {
                rows.insert(n_local, id);
                n_local += 1;
            } else {
                rows.push(id);
            }
        }
        let n_offl = rows.len() - n_local;
        anyhow::ensure!(n_offl == 0 || executor.is_some(), "offloaded rows need an executor");

        let outcome = if n_offl == 0 && self.use_fused_fast_path {
            self.step_fused(&rows, t0)?
        } else {
            self.step_split(&rows, n_local, executor, t0)?
        };

        // Advance per-sequence state.
        for &(id, token) in &outcome.tokens {
            let s = self.seqs.get_mut(&id).expect("stepped seq exists");
            s.token = token;
            s.position += 1;
        }
        self.stats.steps += 1;
        self.stats.local_row_steps += n_local as u64;
        self.stats.offloaded_row_steps += n_offl as u64;
        Ok(outcome)
    }

    /// The fused fast path (whole step = one artifact call).
    fn step_fused(&mut self, rows: &[SeqId], t0: Instant) -> Result<DecodeOutcome> {
        let n = rows.len();
        let bucket = self.runtime.batch_bucket_for(n)?;
        let (l, _s) = (self.runtime.n_layers(), self.runtime.max_seq_len());
        let hd = self.runtime.n_heads() * self.runtime.head_dim();
        let plane = self.runtime.kv_plane();

        let mut tokens = vec![0i32; bucket];
        let mut positions = vec![0i32; bucket];
        for (i, &id) in rows.iter().enumerate() {
            let st = self.seqs[&id];
            tokens[i] = st.token;
            positions[i] = st.position as i32;
        }

        // Gather [L, bucket, S, H, D] caches (padding rows stay zero).
        // §Perf iteration 3: no per-step zeroing — rows beyond each
        // sequence's length (and padded batch rows) are masked by seq_lens
        // inside the attention kernel, so stale scratch bytes are inert.
        let total = l * bucket * plane;
        if self.k_scratch.len() != total {
            self.k_scratch.resize(total, 0.0);
            self.v_scratch.resize(total, 0.0);
        }
        for layer in 0..l {
            let base = layer * bucket * plane;
            self.kv.gather_layer(
                rows,
                layer,
                &mut self.k_scratch[base..base + n * plane],
                &mut self.v_scratch[base..base + n * plane],
            );
        }

        let (next, k_new, v_new) = self.runtime.decode_fused(
            &tokens,
            &positions,
            &self.k_scratch,
            &self.v_scratch,
            bucket,
        )?;

        // Scatter the new KV rows back into the slab.
        for layer in 0..l {
            for (i, &id) in rows.iter().enumerate() {
                let off = (layer * bucket + i) * hd;
                let pos = positions[i] as usize;
                self.kv.write_token(id, layer, pos, &k_new[off..off + hd], &v_new[off..off + hd]);
            }
        }

        self.stats.fused_steps += 1;
        Ok(DecodeOutcome {
            tokens: rows.iter().enumerate().map(|(i, &id)| (id, next[i])).collect(),
            step_s: t0.elapsed().as_secs_f64(),
            local_attn_s: 0.0,
            remote_stall_s: 0.0,
            used_fused: true,
        })
    }

    /// The disaggregated path: layer loop in Rust, attention split
    /// local/remote.
    fn step_split(
        &mut self,
        rows: &[SeqId],
        n_local: usize,
        executor: Option<&ExecutorHandle>,
        t0: Instant,
    ) -> Result<DecodeOutcome> {
        let n = rows.len();
        let n_offl = n - n_local;
        let bucket = self.runtime.batch_bucket_for(n)?;
        let pair = self
            .graph
            .select(n_local, n_offl)
            .ok_or_else(|| anyhow::anyhow!("batch ({n_local},{n_offl}) exceeds bucket grid"))?;
        let hd = self.runtime.n_heads() * self.runtime.head_dim();
        let plane = self.runtime.kv_plane();
        let d = self.runtime.d_model();
        let n_layers = self.runtime.n_layers();

        let mut tokens = vec![0i32; bucket];
        let mut positions = vec![0i32; bucket];
        for (i, &id) in rows.iter().enumerate() {
            let st = self.seqs[&id];
            tokens[i] = st.token;
            positions[i] = st.position as i32;
        }

        let mut hidden = self.runtime.embed(&tokens, bucket)?;
        let mut local_attn_s = 0.0f64;
        let mut remote_stall_s = 0.0f64;

        for layer in 0..n_layers {
            let (q, k_new, v_new) = self.runtime.layer_pre(&hidden, &positions, layer, bucket)?;

            // ② + ③: one packed message, sent before local attention runs.
            if n_offl > 0 {
                let ex = executor.expect("checked by step()");
                let mut qkv = Vec::with_capacity(n_offl * 3 * hd);
                let mut offl_pos = Vec::with_capacity(n_offl);
                for row in n_local..n {
                    qkv.extend_from_slice(&q[row * hd..(row + 1) * hd]);
                    qkv.extend_from_slice(&k_new[row * hd..(row + 1) * hd]);
                    qkv.extend_from_slice(&v_new[row * hd..(row + 1) * hd]);
                    offl_pos.push(positions[row]);
                }
                ex.tx
                    .send(ExecutorMsg::Attn(AttnRequest {
                        layer,
                        ids: rows[n_local..].to_vec(),
                        qkv,
                        positions: offl_pos,
                        bucket: pair.offload.max(n_offl),
                    }))
                    .map_err(|_| anyhow::anyhow!("executor thread gone"))?;
            }

            // Local attention over the local sub-batch.
            let mut attn_out = vec![0.0f32; bucket * d];
            if n_local > 0 {
                let lb = pair.local.max(n_local);
                for (i, &id) in rows[..n_local].iter().enumerate() {
                    let pos = positions[i] as usize;
                    self.kv.write_token(
                        id,
                        layer,
                        pos,
                        &k_new[i * hd..(i + 1) * hd],
                        &v_new[i * hd..(i + 1) * hd],
                    );
                }
                if self.k_scratch.len() != lb * plane {
                    self.k_scratch.resize(lb * plane, 0.0);
                    self.v_scratch.resize(lb * plane, 0.0);
                }
                self.kv.gather_layer(
                    &rows[..n_local],
                    layer,
                    &mut self.k_scratch[..n_local * plane],
                    &mut self.v_scratch[..n_local * plane],
                );
                let mut ql = vec![0.0f32; lb * hd];
                ql[..n_local * hd].copy_from_slice(&q[..n_local * hd]);
                let mut lens = vec![1i32; lb];
                for i in 0..n_local {
                    lens[i] = positions[i] + 1;
                }
                let ta = Instant::now();
                let local_out =
                    self.runtime.attention(&ql, &self.k_scratch, &self.v_scratch, &lens, lb)?;
                local_attn_s += ta.elapsed().as_secs_f64();
                attn_out[..n_local * d].copy_from_slice(&local_out[..n_local * d]);
            }

            // Merge the remote output (blocking only if it hasn't landed).
            if n_offl > 0 {
                let ex = executor.expect("checked");
                let tw = Instant::now();
                let resp = ex
                    .attn_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("executor response channel closed"))?;
                remote_stall_s += tw.elapsed().as_secs_f64();
                anyhow::ensure!(resp.layer == layer, "layer mismatch: {} != {layer}", resp.layer);
                for (j, row) in (n_local..n).enumerate() {
                    attn_out[row * d..(row + 1) * d]
                        .copy_from_slice(&resp.attn_out[j * d..(j + 1) * d]);
                }
            }

            hidden = self.runtime.layer_post(&hidden, &attn_out, layer, bucket)?;
        }

        let next = self.runtime.head(&hidden, bucket)?;
        self.stats.total_stall_s += remote_stall_s;
        Ok(DecodeOutcome {
            tokens: rows.iter().enumerate().map(|(i, &id)| (id, next[i])).collect(),
            step_s: t0.elapsed().as_secs_f64(),
            local_attn_s,
            remote_stall_s,
            used_fused: false,
        })
    }
}
