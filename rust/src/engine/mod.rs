//! Serving engines for the real (CPU PJRT) path: the prefill engine, the
//! decode engine with per-layer attention offloading, and the attention
//! executor colocated with prefill. Each engine owns its own
//! [`crate::runtime::ModelRuntime`] (= its own PJRT client = its own GPU).

pub mod attention_executor;
pub mod decode;
pub mod prefill;
pub mod recovery;
pub mod server;

pub use attention_executor::{AttnRequest, AttnResponse, AttentionExecutor, ExecutorHandle};
pub use decode::{DecodeEngine, DecodeOutcome};
pub use prefill::{PrefillEngine, PrefillResult};
pub use server::{Completion, ServeReport, Server};
