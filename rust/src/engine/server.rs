//! The full real-path serving stack: proxy + prefill instance (with
//! colocated attention executor) on its own thread + decode engine, wired
//! with channels — Fig 7's topology with PJRT CPU clients standing in for
//! the GPUs.
//!
//! Python is nowhere in this path: the server loads `artifacts/` and runs
//! entirely from Rust.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::{ClusterSpec, ModelSpec, ServingConfig};
use crate::coordinator::{GraphCache, OffloadBounds, Proxy};
use crate::metrics::MetricsRecorder;
use crate::runtime::ModelRuntime;
use crate::workload::{Request, RequestId};
use crate::Result;

use super::attention_executor::{run_prefill_instance, ExecutorHandle, ExecutorMsg};
use super::decode::DecodeEngine;
use super::prefill::PrefillResult;
use super::recovery::RecoveryPlan;

/// A finished request's output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Completion {
    pub id: RequestId,
    /// Greedy output tokens (first token from prefill included).
    pub tokens: Vec<i32>,
    pub offloaded: bool,
}

/// End-of-run statistics.
#[derive(Debug)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub metrics: MetricsRecorder,
    pub offloaded_requests: usize,
    pub decode_steps: u64,
    pub fused_steps: u64,
    pub wall_s: f64,
    /// Output tokens/s over the run (the recorder's streaming prefix-sum
    /// window query — the same path the simulator's stable-window
    /// throughput uses).
    pub output_tok_s: f64,
}

struct Active {
    id: RequestId,
    offloaded: bool,
    produced: usize,
    target: usize,
    tokens: Vec<i32>,
    /// Original prompt (kept for executor-failure recompute).
    prompt: Vec<i32>,
}

/// The serving stack.
pub struct Server {
    executor: ExecutorHandle,
    prefill_thread: Option<JoinHandle<Result<()>>>,
    decode: DecodeEngine,
    proxy: Proxy,
    cfg: ServingConfig,
    /// Cleared when the prefill instance / executor stops responding; the
    /// server then degrades to local-only serving (DESIGN.md §7 failure
    /// injection).
    executor_alive: bool,
    /// Executor-failure recoveries performed (observability/tests).
    pub recoveries: u64,
    /// Failure injection for tests: once the decode engine has taken this
    /// many steps, the prefill-instance thread is killed *between* steps,
    /// so the next offloaded batch fails mid-flight and the recovery arm
    /// in [`Server::run_requests`] must re-prefill locally.
    pub fail_executor_after_steps: Option<u64>,
}

impl Server {
    /// Stand up the two instances from an artifact directory. Each
    /// instance thread loads its own runtime (its own PJRT client — the
    /// process analogue of its own GPU).
    pub fn start(artifact_dir: &std::path::Path, cfg: ServingConfig) -> Result<Server> {
        let mut decode_rt = ModelRuntime::load(artifact_dir)?;
        decode_rt.warmup()?;

        // A malformed bucket config fails here, at startup, not mid-serve.
        let graph = GraphCache::try_new(&cfg.decode_buckets, &cfg.offload_buckets, None)?;
        let decode = DecodeEngine::new(decode_rt, graph);

        // Offload bounds for the CPU testbed: OB_mem comes from the
        // cluster's bandwidth/capacity ratios (Eq 1); the compute-side
        // profile is the executable grid itself — the decode instance
        // comfortably meets TPOT at half the largest bucket (B_TPOT) and
        // the grid caps the batch at the largest bucket (B_max).
        let max_bucket = decode.runtime.manifest.batch_buckets.iter().copied().max().unwrap();
        let mut bounds = OffloadBounds::compute(
            &ClusterSpec::paper_default(),
            &ModelSpec::tiny(),
            &cfg.slo,
            64,
        );
        bounds.b_max = max_bucket;
        bounds.set_b_tpot(cfg.b_max_override.unwrap_or(max_bucket / 2));
        let proxy = Proxy::new(cfg.offload, bounds, 1, 1);

        let (tx, rx) = channel::<ExecutorMsg>();
        let (attn_tx, attn_rx) = channel();
        let (ready_tx, ready_rx) = channel();
        let dir = artifact_dir.to_path_buf();
        let prefill_thread =
            std::thread::spawn(move || run_prefill_instance(dir, rx, attn_tx, ready_tx));
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("prefill instance died during startup"))??;

        Ok(Server {
            executor: ExecutorHandle { tx: tx.clone(), attn_rx },
            prefill_thread: Some(prefill_thread),
            decode,
            proxy,
            cfg,
            executor_alive: true,
            recoveries: 0,
            fail_executor_after_steps: None,
        })
    }

    /// Deliberately stop the prefill-instance thread (failure injection
    /// for tests: the server must recover by re-prefilling offloaded
    /// requests locally).
    pub fn kill_executor(&mut self) {
        let _ = self.tx().send(ExecutorMsg::Shutdown);
        if let Some(h) = self.prefill_thread.take() {
            let _ = h.join();
        }
        self.executor_alive = false;
    }

    pub fn executor_alive(&self) -> bool {
        self.executor_alive
    }

    fn tx(&self) -> &Sender<ExecutorMsg> {
        &self.executor.tx
    }

    /// Serve a list of requests to completion with continuous batching.
    /// `force_offload` overrides the proxy for tests (None = Algorithm 1 /
    /// configured policy).
    pub fn run_requests(
        &mut self,
        requests: &[Request],
        force_offload: Option<bool>,
    ) -> Result<ServeReport> {
        let wall0 = Instant::now();
        // Drop any stale attention responses from a previous (possibly
        // aborted) run before reusing the channel.
        while self.executor.attn_rx.try_recv().is_ok() {}
        let mut metrics = MetricsRecorder::new();
        let mut pending: std::collections::VecDeque<&Request> = requests.iter().collect();
        let mut active: Vec<Active> = Vec::new();
        let mut completions = Vec::new();
        let max_batch = self.decode.runtime.manifest.batch_buckets.iter().copied().max().unwrap();
        let max_seq = self.decode.runtime.max_seq_len();
        let mut offloaded_requests = 0usize;

        // Capacity accounting for this run (Eq 1's HBM_pi / HBM_d on the
        // real path): reserved = prompt + target output per resident
        // request; requests that don't fit the executor pool fall back to
        // local, requests that don't fit the local pool wait.
        let mut executor_resident = 0usize;
        let mut local_resident = 0usize;

        while !pending.is_empty() || !active.is_empty() {
            // Admit while there is batch room.
            while active.len() < max_batch && !pending.is_empty() {
                let req = *pending.front().unwrap();
                let reserve = (req.prompt_len + req.output_len).min(max_seq);
                let local_fits = self
                    .cfg
                    .decode_kv_capacity_tokens
                    .is_none_or(|cap| local_resident + reserve <= cap);
                let executor_fits = self
                    .cfg
                    .executor_kv_capacity_tokens
                    .is_none_or(|cap| executor_resident + reserve <= cap);

                let route = self.proxy.route(req);
                let mut offloaded = self.executor_alive
                    && force_offload.unwrap_or(route.offload.offloaded());
                if offloaded && !executor_fits {
                    offloaded = false; // executor pool full: serve locally
                }
                if !offloaded && !local_fits {
                    anyhow::ensure!(
                        !active.is_empty(),
                        "request {} ({} tokens) exceeds the decode KV capacity",
                        req.id,
                        reserve
                    );
                    break; // wait for the batch to drain
                }
                pending.pop_front();
                metrics.on_arrival(req.id, wall0.elapsed().as_secs_f64());
                anyhow::ensure!(
                    !req.prompt_tokens.is_empty(),
                    "real-path requests need prompt tokens (use with_tokens)"
                );
                if offloaded {
                    executor_resident += reserve;
                } else {
                    local_resident += reserve;
                }
                let prompt: Vec<i32> =
                    req.prompt_tokens.iter().map(|&t| t as i32).collect();

                let pr: PrefillResult = if self.executor_alive {
                    if offloaded {
                        // ① hint before the prefill (metadata init off the
                        // critical path).
                        self.tx()
                            .send(ExecutorMsg::Hint { id: req.id, prompt_len: prompt.len() })
                            .map_err(|_| anyhow::anyhow!("executor gone"))?;
                    }
                    let (rtx, rrx) = channel();
                    self.tx()
                        .send(ExecutorMsg::Prefill {
                            id: req.id,
                            prompt: prompt.clone(),
                            reply: rtx,
                        })
                        .map_err(|_| anyhow::anyhow!("executor gone"))?;
                    rrx.recv().map_err(|_| anyhow::anyhow!("prefill reply lost"))??
                } else {
                    // Degraded mode: the prefill instance is gone; run the
                    // prompt on the decode instance (colocated-prefill
                    // fallback).
                    let out = self.decode.runtime.prefill(&prompt)?;
                    PrefillResult {
                        id: req.id,
                        first_token: out.first_token,
                        k_cache: out.k_cache,
                        v_cache: out.v_cache,
                        bucket: out.bucket,
                        prompt_len: prompt.len(),
                        latency_s: 0.0,
                    }
                };
                metrics.on_first_token(req.id, wall0.elapsed().as_secs_f64());

                if offloaded {
                    offloaded_requests += 1;
                    // KV stays colocated with the executor.
                    self.tx()
                        .send(ExecutorMsg::AdmitKv {
                            id: req.id,
                            k: pr.k_cache,
                            v: pr.v_cache,
                            bucket_seq: pr.bucket,
                            tokens: pr.prompt_len,
                        })
                        .map_err(|_| anyhow::anyhow!("executor gone"))?;
                    self.decode.admit_offloaded(req.id, pr.first_token, pr.prompt_len);
                } else {
                    // KV "transfers" to the decode instance.
                    self.decode.admit_local(
                        req.id,
                        pr.first_token,
                        pr.prompt_len,
                        &pr.k_cache,
                        &pr.v_cache,
                        pr.bucket,
                    );
                }
                let target = req.output_len.min(max_seq - req.prompt_len);
                active.push(Active {
                    id: req.id,
                    offloaded,
                    produced: 1,
                    target: target.max(1),
                    tokens: vec![pr.first_token],
                    prompt,
                });
            }

            if active.is_empty() {
                continue;
            }

            // Retire sequences that already met their target (e.g. 1-token
            // outputs) before stepping.
            let mut still: Vec<Active> = Vec::new();
            for a in active.drain(..) {
                if a.produced >= a.target {
                    self.retire(
                        &a,
                        &mut metrics,
                        wall0,
                        &mut completions,
                        &mut executor_resident,
                        &mut local_resident,
                        max_seq,
                    )?;
                } else {
                    still.push(a);
                }
            }
            active = still;
            if active.is_empty() {
                continue;
            }

            // One decode step over the whole active batch.
            if let Some(n) = self.fail_executor_after_steps {
                if self.executor_alive && self.decode.stats.steps >= n {
                    self.kill_executor();
                }
            }
            let ids: Vec<u64> = active.iter().map(|a| a.id).collect();
            let outcome = match self.decode.step(&ids, Some(&self.executor)) {
                Ok(o) => o,
                Err(e) => {
                    let plan = RecoveryPlan::classify(
                        active.iter().map(|a| (a.id, a.offloaded)),
                    );
                    if plan.is_empty() {
                        return Err(e); // not an executor failure; propagate
                    }
                    // Executor failure: its KV is gone. Re-prefill the
                    // offloaded requests locally (recompute, like vLLM
                    // preemption) and continue in degraded mode.
                    self.executor_alive = false;
                    while self.executor.attn_rx.try_recv().is_ok() {}
                    for a in active.iter_mut().filter(|a| a.offloaded) {
                        self.decode.release(a.id);
                        let mut new_prompt = a.prompt.clone();
                        new_prompt.extend_from_slice(&a.tokens);
                        if new_prompt.len() >= max_seq {
                            a.target = a.produced; // retire next pass
                            a.offloaded = false;
                            continue;
                        }
                        let out = self.decode.runtime.prefill(&new_prompt)?;
                        self.decode.admit_local(
                            a.id,
                            out.first_token,
                            new_prompt.len(),
                            &out.k_cache,
                            &out.v_cache,
                            out.bucket,
                        );
                        a.tokens.push(out.first_token);
                        a.produced += 1;
                        a.offloaded = false;
                        metrics.on_token(a.id, wall0.elapsed().as_secs_f64());
                        self.recoveries += 1;
                    }
                    continue;
                }
            };
            let now = wall0.elapsed().as_secs_f64();
            for (id, tok) in outcome.tokens {
                if let Some(a) = active.iter_mut().find(|a| a.id == id) {
                    a.tokens.push(tok);
                    a.produced += 1;
                    metrics.on_token(id, now);
                    self.proxy.on_token(0, id);
                }
            }
        }

        let wall_s = wall0.elapsed().as_secs_f64();
        let output_tok_s = metrics.throughput_in_window(0.0, wall_s);
        Ok(ServeReport {
            completions,
            metrics,
            offloaded_requests,
            decode_steps: self.decode.stats.steps,
            fused_steps: self.decode.stats.fused_steps,
            wall_s,
            output_tok_s,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn retire(
        &mut self,
        a: &Active,
        metrics: &mut MetricsRecorder,
        wall0: Instant,
        completions: &mut Vec<Completion>,
        executor_resident: &mut usize,
        local_resident: &mut usize,
        max_seq: usize,
    ) -> Result<()> {
        let reserve = (a.prompt.len() + a.target).min(max_seq);
        if a.offloaded {
            *executor_resident = executor_resident.saturating_sub(reserve);
        } else {
            *local_resident = local_resident.saturating_sub(reserve);
        }
        metrics.on_finished(a.id, wall0.elapsed().as_secs_f64());
        self.proxy.on_finished(0, a.id);
        if let Some(was_offloaded) = self.decode.release(a.id) {
            if was_offloaded {
                self.tx()
                    .send(ExecutorMsg::Release { id: a.id })
                    .map_err(|_| anyhow::anyhow!("executor gone"))?;
            }
        }
        completions.push(Completion {
            id: a.id,
            tokens: a.tokens.clone(),
            offloaded: a.offloaded,
        });
        Ok(())
    }

    /// Toggle the fused no-offload fast path (ablation).
    pub fn set_fused_fast_path(&mut self, on: bool) {
        self.decode.use_fused_fast_path = on;
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx().send(ExecutorMsg::Shutdown);
        if let Some(h) = self.prefill_thread.take() {
            let _ = h.join();
        }
    }
}
