//! Executor-failure recovery: when the attention executor (or its whole
//! prefill instance) dies mid-flight, the offloaded requests' KV is gone —
//! it lived in that instance's HBM. The recovery path mirrors preemption:
//! re-prefill the affected requests *locally* (prompt + already-generated
//! tokens) and continue decoding with local attention.
//!
//! This is deliberately the same mechanism vLLM uses for preempted
//! requests (recompute), so the decode engine needs no new state: the
//! server drives it (see `Server::run_requests`' failure arm and the
//! `executor_failure_*` integration tests in `rust/tests/e2e_serving.rs` —
//! in particular `executor_failure_arm_recomputes_offloaded_requests`,
//! which kills the executor between decode steps via
//! `Server::fail_executor_after_steps` and pins oracle-exact recovery).
//! The cluster simulator mirrors this path at fleet scale: its fault
//! plane (`sim/cluster.rs`, `FaultConfig`) recomputes crash victims with
//! the same prompt-plus-generated replay.

use crate::workload::RequestId;

/// What the server must do for each in-flight request after an executor
/// failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Request was local: untouched, keeps decoding.
    KeepLocal,
    /// Request was offloaded: KV lost; re-prefill `prompt ++ generated`
    /// and re-admit as local.
    RecomputeLocal,
}

/// Recovery plan for a batch.
#[derive(Debug, Default)]
pub struct RecoveryPlan {
    pub keep: Vec<RequestId>,
    pub recompute: Vec<RequestId>,
}

impl RecoveryPlan {
    /// Classify the active set by offload status.
    pub fn classify(active: impl IntoIterator<Item = (RequestId, bool)>) -> RecoveryPlan {
        let mut plan = RecoveryPlan::default();
        for (id, offloaded) in active {
            if offloaded {
                plan.recompute.push(id);
            } else {
                plan.keep.push(id);
            }
        }
        plan
    }

    pub fn is_empty(&self) -> bool {
        self.recompute.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_splits_by_offload() {
        let plan =
            RecoveryPlan::classify([(1, false), (2, true), (3, true), (4, false)]);
        assert_eq!(plan.keep, vec![1, 4]);
        assert_eq!(plan.recompute, vec![2, 3]);
        assert!(!plan.is_empty());
    }

    #[test]
    fn all_local_is_empty_plan() {
        let plan = RecoveryPlan::classify([(1, false)]);
        assert!(plan.is_empty());
    }
}
