//! Fixed-size KV block allocator with a free list.

/// Index of a KV block within the pool.
pub type BlockId = u32;

/// Allocator over `num_blocks` blocks of `block_tokens` tokens each.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    block_tokens: usize,
    num_blocks: usize,
    free: Vec<BlockId>,
}

impl BlockAllocator {
    pub fn new(num_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        assert!(num_blocks <= BlockId::MAX as usize);
        // LIFO free list: most-recently-freed block is reused first (cache
        // friendliness in the slab path).
        let free = (0..num_blocks as BlockId).rev().collect();
        BlockAllocator { block_tokens, num_blocks, free }
    }

    /// Capacity sized from a byte budget (how deployments configure it).
    pub fn from_bytes(budget_bytes: f64, bytes_per_token: f64, block_tokens: usize) -> Self {
        let tokens = (budget_bytes / bytes_per_token).max(0.0) as usize;
        Self::new(tokens / block_tokens, block_tokens)
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Tokens representable by the currently-free blocks.
    pub fn free_token_capacity(&self) -> usize {
        self.free_blocks() * self.block_tokens
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocate one block. `None` when exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        self.free.pop()
    }

    /// Allocate `n` blocks atomically: all or none.
    pub fn alloc_n(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if self.free.len() < n {
            return None;
        }
        Some(self.free.split_off(self.free.len() - n))
    }

    /// Allocate `n` blocks atomically into `out` (all or none; returns
    /// whether the allocation succeeded). The no-temporary variant of
    /// [`BlockAllocator::alloc_n`] for the bulk append path: same block
    /// order as `alloc_n`, no intermediate `Vec`.
    pub fn alloc_n_into(&mut self, n: usize, out: &mut Vec<BlockId>) -> bool {
        if self.free.len() < n {
            return false;
        }
        let start = self.free.len() - n;
        out.extend(self.free.drain(start..));
        true
    }

    /// Return a block to the pool.
    ///
    /// Double-free is a logic bug upstream; debug builds assert.
    pub fn free(&mut self, id: BlockId) {
        debug_assert!((id as usize) < self.num_blocks, "block id out of range");
        debug_assert!(!self.free.contains(&id), "double free of block {id}");
        self.free.push(id);
    }

    pub fn free_all(&mut self, ids: impl IntoIterator<Item = BlockId>) {
        for id in ids {
            self.free(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_exhausted() {
        let mut a = BlockAllocator::new(4, 16);
        let mut got = vec![];
        while let Some(b) = a.alloc() {
            got.push(b);
        }
        assert_eq!(got.len(), 4);
        assert_eq!(a.free_blocks(), 0);
        assert_eq!(a.used_blocks(), 4);
        // All distinct.
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn free_returns_capacity() {
        let mut a = BlockAllocator::new(2, 16);
        let b0 = a.alloc().unwrap();
        assert_eq!(a.free_blocks(), 1);
        a.free(b0);
        assert_eq!(a.free_blocks(), 2);
    }

    #[test]
    fn alloc_n_is_atomic() {
        let mut a = BlockAllocator::new(3, 16);
        assert!(a.alloc_n(4).is_none());
        assert_eq!(a.free_blocks(), 3, "failed alloc_n must not leak");
        let blocks = a.alloc_n(3).unwrap();
        assert_eq!(blocks.len(), 3);
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    fn alloc_n_into_matches_alloc_n() {
        // Same block-id order, same atomicity, no temporary.
        let mut a = BlockAllocator::new(6, 16);
        let mut b = BlockAllocator::new(6, 16);
        let want = a.alloc_n(4).unwrap();
        let mut got = vec![999]; // pre-existing entries must survive
        assert!(b.alloc_n_into(4, &mut got));
        assert_eq!(&got[1..], want.as_slice());
        assert_eq!(a.free_blocks(), b.free_blocks());
        // All-or-none on failure.
        let len_before = got.len();
        assert!(!b.alloc_n_into(3, &mut got));
        assert_eq!(got.len(), len_before, "failed alloc_n_into must not push");
        assert_eq!(b.free_blocks(), 2);
        assert!(b.alloc_n_into(2, &mut got));
        assert_eq!(b.free_blocks(), 0);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let a = BlockAllocator::new(10, 16);
        assert_eq!(a.blocks_for(0), 0);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
    }

    #[test]
    fn from_bytes_capacity() {
        // 1 MiB budget, 512 B/token, 16-token blocks -> 2048 tokens -> 128 blocks.
        let a = BlockAllocator::from_bytes(1048576.0, 512.0, 16);
        assert_eq!(a.num_blocks(), 128);
    }

    #[test]
    #[should_panic]
    fn zero_block_tokens_rejected() {
        let _ = BlockAllocator::new(4, 0);
    }
}
