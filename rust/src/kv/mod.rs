//! Paged KV-cache management (vLLM-style PagedAttention block tables).
//!
//! Two cooperating pieces:
//!
//! * [`BlockAllocator`] — fixed-size token blocks over a bounded pool with
//!   a free list; the unit of HBM accounting on both decode instances and
//!   the attention executor's offload pool.
//! * [`KvPool`] — per-sequence block tables on top of the allocator:
//!   append tokens, query capacity, pick preemption victims when the pool
//!   saturates (the event behind the paper's OpenThoughts TPOT spikes),
//!   and release on completion.
//!
//! The *real* CPU serving path additionally stores tensor data per slot
//! ([`slab::KvSlab`]); the simulator only needs the accounting.

mod block;
mod pool;
pub mod slab;

pub use block::{BlockAllocator, BlockId};
pub use pool::{KvPool, SeqId, SeqKv};
