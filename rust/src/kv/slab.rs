//! Tensor-carrying KV storage for the real (CPU PJRT) serving path.
//!
//! Each sequence owns a contiguous f32 slab laid out `[L, S, H, D]` for K
//! and V. The decode engine gathers per-layer, per-batch views into the
//! `[B, S, H, D]` input buffers of the attention artifact, and scatters the
//! `layer_pre` outputs back at the step position. (The A100-scale simulator
//! never touches this module — it only needs the block accounting.)

use std::collections::HashMap;

use super::pool::SeqId;

/// Shape metadata for a KV slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvShape {
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_heads: usize,
    pub head_dim: usize,
}

impl KvShape {
    pub fn per_token(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn per_layer(&self) -> usize {
        self.max_seq * self.per_token()
    }

    pub fn total(&self) -> usize {
        self.n_layers * self.per_layer()
    }
}

/// One sequence's K and V tensors.
#[derive(Debug, Clone)]
pub struct SeqSlab {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Valid tokens written so far.
    pub len: usize,
}

/// KV tensor store keyed by sequence.
#[derive(Debug)]
pub struct KvSlab {
    shape: KvShape,
    seqs: HashMap<SeqId, SeqSlab>,
}

impl KvSlab {
    pub fn new(shape: KvShape) -> Self {
        KvSlab { shape, seqs: HashMap::new() }
    }

    pub fn shape(&self) -> KvShape {
        self.shape
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// Insert a sequence from prefill output. `k`/`v` are `[L, P, H, D]`
    /// (prompt-bucket leading dims) with `tokens` valid positions.
    pub fn insert_from_prefill(
        &mut self,
        id: SeqId,
        k: &[f32],
        v: &[f32],
        bucket_seq: usize,
        tokens: usize,
    ) {
        let sh = self.shape;
        assert!(tokens <= bucket_seq && tokens <= sh.max_seq);
        assert_eq!(k.len(), sh.n_layers * bucket_seq * sh.per_token());
        assert_eq!(v.len(), k.len());
        let mut slab = SeqSlab {
            k: vec![0.0; sh.total()],
            v: vec![0.0; sh.total()],
            len: tokens,
        };
        let pt = sh.per_token();
        for l in 0..sh.n_layers {
            let src = l * bucket_seq * pt;
            let dst = l * sh.per_layer();
            slab.k[dst..dst + tokens * pt].copy_from_slice(&k[src..src + tokens * pt]);
            slab.v[dst..dst + tokens * pt].copy_from_slice(&v[src..src + tokens * pt]);
        }
        self.seqs.insert(id, slab);
    }

    /// Write one new token's K/V rows for a single layer at `pos`.
    /// `k_row`/`v_row` are `[H, D]`.
    pub fn write_token(
        &mut self,
        id: SeqId,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let sh = self.shape;
        let pt = sh.per_token();
        assert_eq!(k_row.len(), pt);
        assert_eq!(v_row.len(), pt);
        assert!(pos < sh.max_seq, "pos {pos} >= max_seq {}", sh.max_seq);
        let slab = self.seqs.get_mut(&id).expect("unknown sequence");
        let off = layer * sh.per_layer() + pos * pt;
        slab.k[off..off + pt].copy_from_slice(k_row);
        slab.v[off..off + pt].copy_from_slice(v_row);
        // Advance the valid length immediately: the per-layer decode loop
        // writes layer l's new row and then gathers layer l for attention,
        // so the row written *this* call must be visible to the very next
        // gather. (Rows for layers > l at this position are written before
        // their own gathers — the call order guarantees it.)
        slab.len = slab.len.max(pos + 1);
    }

    /// Gather one layer of a batch of sequences into `[B, S, H, D]` output
    /// buffers (the attention artifact's kv inputs). Buffers must be
    /// `batch.len() * per_layer()` long; rows beyond each sequence's length
    /// are left as-is (callers pass zeroed or reused scratch — masked by
    /// seq_lens in the kernel).
    pub fn gather_layer(
        &self,
        batch: &[SeqId],
        layer: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let sh = self.shape;
        let per_layer = sh.per_layer();
        assert_eq!(k_out.len(), batch.len() * per_layer);
        assert_eq!(v_out.len(), k_out.len());
        let pt = sh.per_token();
        for (bi, id) in batch.iter().enumerate() {
            let slab = self.seqs.get(id).expect("unknown sequence");
            let src = layer * per_layer;
            let n = slab.len * pt;
            let dst = bi * per_layer;
            k_out[dst..dst + n].copy_from_slice(&slab.k[src..src + n]);
            v_out[dst..dst + n].copy_from_slice(&slab.v[src..src + n]);
        }
    }

    pub fn remove(&mut self, id: SeqId) -> bool {
        self.seqs.remove(&id).is_some()
    }

    /// Extract a sequence's full slab (for KV transfer decode → executor,
    /// or executor hand-back).
    pub fn extract(&mut self, id: SeqId) -> Option<SeqSlab> {
        self.seqs.remove(&id)
    }

    /// Insert a previously-extracted slab (the receiving side of a KV
    /// transfer).
    pub fn insert_slab(&mut self, id: SeqId, slab: SeqSlab) {
        assert_eq!(slab.k.len(), self.shape.total());
        self.seqs.insert(id, slab);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> KvShape {
        KvShape { n_layers: 2, max_seq: 8, n_heads: 2, head_dim: 4 }
    }

    #[test]
    fn shape_arithmetic() {
        let sh = shape();
        assert_eq!(sh.per_token(), 8);
        assert_eq!(sh.per_layer(), 64);
        assert_eq!(sh.total(), 128);
    }

    #[test]
    fn prefill_insert_then_gather() {
        let sh = shape();
        let mut slab = KvSlab::new(sh);
        let bucket = 4;
        let tokens = 3;
        // Distinct values per (layer, pos): k = 100*l + 10*pos + i
        let mut k = vec![0.0; sh.n_layers * bucket * sh.per_token()];
        for l in 0..sh.n_layers {
            for p in 0..bucket {
                for i in 0..sh.per_token() {
                    k[(l * bucket + p) * sh.per_token() + i] =
                        (100 * l + 10 * p + i) as f32;
                }
            }
        }
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        slab.insert_from_prefill(1, &k, &v, bucket, tokens);
        assert_eq!(slab.seq_len(1), Some(3));

        let mut k_out = vec![0.0; sh.per_layer()];
        let mut v_out = vec![0.0; sh.per_layer()];
        slab.gather_layer(&[1], 1, &mut k_out, &mut v_out);
        // Layer 1, pos 2, elem 5 => 100 + 20 + 5.
        assert_eq!(k_out[2 * sh.per_token() + 5], 125.0);
        assert_eq!(v_out[2 * sh.per_token() + 5], -125.0);
        // Beyond len: zero (scratch was zeroed).
        assert_eq!(k_out[3 * sh.per_token()], 0.0);
    }

    #[test]
    fn write_token_advances_len_immediately() {
        let sh = shape();
        let mut slab = KvSlab::new(sh);
        slab.insert_from_prefill(5, &vec![0.0; 128], &vec![0.0; 128], sh.max_seq, 2);
        let row = vec![7.0; sh.per_token()];
        slab.write_token(5, 0, 2, &row, &row);
        assert_eq!(slab.seq_len(5), Some(3), "len advances on first write at pos");
        slab.write_token(5, 1, 2, &row, &row);
        assert_eq!(slab.seq_len(5), Some(3));
        let mut k_out = vec![0.0; sh.per_layer()];
        let mut v_out = vec![0.0; sh.per_layer()];
        slab.gather_layer(&[5], 1, &mut k_out, &mut v_out);
        assert_eq!(k_out[2 * sh.per_token()], 7.0);
    }

    #[test]
    fn extract_and_reinsert_roundtrip() {
        let sh = shape();
        let mut a = KvSlab::new(sh);
        let mut b = KvSlab::new(sh);
        a.insert_from_prefill(9, &vec![1.5; 128], &vec![2.5; 128], sh.max_seq, 4);
        let s = a.extract(9).unwrap();
        assert!(!a.contains(9));
        b.insert_slab(9, s);
        assert_eq!(b.seq_len(9), Some(4));
        let mut k_out = vec![0.0; sh.per_layer()];
        let mut v_out = vec![0.0; sh.per_layer()];
        b.gather_layer(&[9], 0, &mut k_out, &mut v_out);
        assert_eq!(k_out[0], 1.5);
        assert_eq!(v_out[0], 2.5);
    }

    #[test]
    fn gather_multi_sequence_batch() {
        let sh = shape();
        let mut slab = KvSlab::new(sh);
        slab.insert_from_prefill(1, &vec![1.0; 128], &vec![1.0; 128], sh.max_seq, 2);
        slab.insert_from_prefill(2, &vec![2.0; 128], &vec![2.0; 128], sh.max_seq, 5);
        let mut k_out = vec![0.0; 2 * sh.per_layer()];
        let mut v_out = vec![0.0; 2 * sh.per_layer()];
        slab.gather_layer(&[2, 1], 0, &mut k_out, &mut v_out);
        assert_eq!(k_out[0], 2.0); // first row of seq 2
        assert_eq!(k_out[sh.per_layer()], 1.0); // first row of seq 1
    }
}
