//! Per-sequence block tables over the [`BlockAllocator`], with preemption.

use std::collections::HashMap;

use super::block::{BlockAllocator, BlockId};

/// Opaque sequence (request) identifier.
pub type SeqId = u64;

/// One sequence's KV state: its block table and logical token length.
#[derive(Debug, Clone)]
pub struct SeqKv {
    pub blocks: Vec<BlockId>,
    pub tokens: usize,
}

impl SeqKv {
    /// Token capacity of the currently-held blocks.
    fn capacity(&self, block_tokens: usize) -> usize {
        self.blocks.len() * block_tokens
    }
}

/// KV pool: sequences → block tables, growth, and preemption.
#[derive(Debug)]
pub struct KvPool {
    alloc: BlockAllocator,
    seqs: HashMap<SeqId, SeqKv>,
    /// Admission order, for vLLM-style last-come-first-preempted victims.
    order: Vec<SeqId>,
}

impl KvPool {
    pub fn new(alloc: BlockAllocator) -> Self {
        KvPool { alloc, seqs: HashMap::new(), order: Vec::new() }
    }

    pub fn block_tokens(&self) -> usize {
        self.alloc.block_tokens()
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn total_blocks(&self) -> usize {
        self.alloc.num_blocks()
    }

    /// Total tokens resident across all sequences.
    pub fn resident_tokens(&self) -> usize {
        self.seqs.values().map(|s| s.tokens).sum()
    }

    /// Pool saturation in [0, 1] (block granularity) for an explicit
    /// block count — the single home of the `0 blocks ⇒ saturated`
    /// convention, shared with callers that replay occupancy from
    /// planned allocation counts without touching the pool (the sim's
    /// leap engine, fed by [`KvPool::plan_bulk_steps`]).
    pub fn occupancy_of(used_blocks: usize, total_blocks: usize) -> f64 {
        if total_blocks == 0 {
            return 1.0;
        }
        used_blocks as f64 / total_blocks as f64
    }

    /// Pool saturation in [0, 1] (block granularity).
    pub fn occupancy(&self) -> f64 {
        Self::occupancy_of(self.alloc.used_blocks(), self.alloc.num_blocks())
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq(&self, id: SeqId) -> Option<&SeqKv> {
        self.seqs.get(&id)
    }

    /// Can a new sequence of `tokens` tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.alloc.free_blocks() >= self.alloc.blocks_for(tokens)
    }

    /// Admit a sequence with `tokens` already present (its prefill KV).
    /// Fails (without side effects) when blocks are unavailable.
    pub fn admit(&mut self, id: SeqId, tokens: usize) -> Result<(), KvError> {
        if self.seqs.contains_key(&id) {
            return Err(KvError::DuplicateSeq(id));
        }
        let need = self.alloc.blocks_for(tokens.max(1));
        let blocks = self.alloc.alloc_n(need).ok_or(KvError::OutOfBlocks {
            requested: need,
            available: self.alloc.free_blocks(),
        })?;
        self.seqs.insert(id, SeqKv { blocks, tokens });
        self.order.push(id);
        Ok(())
    }

    /// Append one generated token to a sequence, growing its table by a
    /// block when it crosses a boundary.
    pub fn append_token(&mut self, id: SeqId) -> Result<(), KvError> {
        let block_tokens = self.alloc.block_tokens();
        let seq = self.seqs.get_mut(&id).ok_or(KvError::UnknownSeq(id))?;
        if seq.tokens + 1 > seq.capacity(block_tokens) {
            let blk = self.alloc.alloc().ok_or(KvError::OutOfBlocks {
                requested: 1,
                available: 0,
            })?;
            seq.blocks.push(blk);
        }
        seq.tokens += 1;
        Ok(())
    }

    /// Append `n` generated tokens to a sequence at once — the decode
    /// leap engine's bulk path. Block math is deterministic, so this
    /// allocates exactly the blocks `n` successive
    /// [`KvPool::append_token`] calls would have; the allocation is
    /// atomic (on failure nothing is mutated — callers size `n` with
    /// [`KvPool::plan_bulk_steps`] so the bulk path never fails).
    pub fn append_tokens(&mut self, id: SeqId, n: usize) -> Result<(), KvError> {
        if n == 0 {
            return Ok(());
        }
        let block_tokens = self.alloc.block_tokens();
        let seq = self.seqs.get_mut(&id).ok_or(KvError::UnknownSeq(id))?;
        let need = (seq.tokens + n).div_ceil(block_tokens);
        if need > seq.blocks.len() {
            let extra = need - seq.blocks.len();
            if !self.alloc.alloc_n_into(extra, &mut seq.blocks) {
                return Err(KvError::OutOfBlocks {
                    requested: extra,
                    available: self.alloc.free_blocks(),
                });
            }
        }
        seq.tokens += n;
        Ok(())
    }

    /// Plan a run of whole-pool append steps — one token appended to
    /// *every* resident sequence per step, the decode leap engine's
    /// frozen-batch model. Returns the largest `k <= max_steps` for which
    /// all `k` steps' block allocations succeed against the current free
    /// list, and fills `allocs_out[i]` with the number of blocks step
    /// `i + 1` allocates (truncated to the returned `k`), so callers can
    /// replay the pool-occupancy series without touching the pool.
    ///
    /// Each sequence crosses a block boundary exactly when its pre-append
    /// length is a whole number of blocks, i.e. every `block_tokens`
    /// steps at a phase fixed by its current length — so a residue
    /// histogram prices every step in O(1).
    pub fn plan_bulk_steps(&self, max_steps: usize, allocs_out: &mut Vec<u32>) -> usize {
        allocs_out.clear();
        self.plan_bulk_inner(max_steps, Some(allocs_out))
    }

    /// [`KvPool::plan_bulk_steps`] without the allocation series — just
    /// the largest feasible `k`. The sim's epoch engine uses this for
    /// decode instances whose occupancy timeline is not reported (only
    /// instance 0's is), skipping the series fill on the hot path.
    pub fn bulk_horizon(&self, max_steps: usize) -> usize {
        self.plan_bulk_inner(max_steps, None)
    }

    fn plan_bulk_inner(&self, max_steps: usize, mut allocs_out: Option<&mut Vec<u32>>) -> usize {
        if max_steps == 0 {
            return 0;
        }
        if self.seqs.is_empty() {
            if let Some(out) = allocs_out {
                out.resize(max_steps, 0);
            }
            return max_steps;
        }
        let bt = self.alloc.block_tokens();
        // Residue histogram on the stack for real-world block sizes (16
        // by default); the heap fallback only triggers for exotic
        // configurations, keeping the leap hot path allocation-free.
        let mut stack_hist = [0u32; 64];
        let mut heap_hist: Vec<u32>;
        let hist: &mut [u32] = if bt <= stack_hist.len() {
            &mut stack_hist[..bt]
        } else {
            heap_hist = vec![0u32; bt];
            &mut heap_hist
        };
        for seq in self.seqs.values() {
            if seq.tokens == 0 {
                // Over-provisioned corner (a block table ahead of its
                // token count): the phase math below would be wrong, so
                // refuse to plan and let the per-step path handle it.
                return 0;
            }
            debug_assert_eq!(
                seq.blocks.len(),
                seq.tokens.div_ceil(bt),
                "sequence block table out of lock-step with its token count"
            );
            hist[seq.tokens % bt] += 1;
        }
        let mut free = self.alloc.free_blocks() as u64;
        for i in 1..=max_steps {
            // A sequence holding `tokens ≡ r (mod bt)` allocates at step
            // `i` iff `(r + i - 1) ≡ 0 (mod bt)`.
            let r = (bt - (i - 1) % bt) % bt;
            let allocs = hist[r];
            if u64::from(allocs) > free {
                return i - 1;
            }
            free -= u64::from(allocs);
            if let Some(out) = allocs_out.as_deref_mut() {
                out.push(allocs);
            }
        }
        max_steps
    }

    /// Release a sequence, returning its blocks to the pool.
    pub fn release(&mut self, id: SeqId) -> Result<usize, KvError> {
        let seq = self.seqs.remove(&id).ok_or(KvError::UnknownSeq(id))?;
        let n = seq.blocks.len();
        self.alloc.free_all(seq.blocks);
        self.order.retain(|&s| s != id);
        Ok(n)
    }

    /// Pick the preemption victim: the most recently admitted sequence
    /// (vLLM's recompute-preemption order — newest requests have the least
    /// sunk decode work).
    pub fn preemption_victim(&self) -> Option<SeqId> {
        self.order.last().copied()
    }

    /// Preempt (evict) the victim, freeing its blocks. Returns the evicted
    /// sequence's id and token count so the scheduler can re-queue it for
    /// recompute.
    pub fn preempt(&mut self) -> Option<(SeqId, usize)> {
        let victim = self.preemption_victim()?;
        let tokens = self.seqs[&victim].tokens;
        self.release(victim).expect("victim exists");
        Some((victim, tokens))
    }

    pub fn seq_ids(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.order.iter().copied()
    }
}

/// KV pool errors.
#[derive(Debug, PartialEq, Eq)]
pub enum KvError {
    DuplicateSeq(SeqId),
    UnknownSeq(SeqId),
    OutOfBlocks { requested: usize, available: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::DuplicateSeq(id) => write!(f, "sequence {id} already admitted"),
            KvError::UnknownSeq(id) => write!(f, "sequence {id} not found"),
            KvError::OutOfBlocks { requested, available } => {
                write!(f, "out of KV blocks: requested {requested}, available {available}")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: usize) -> KvPool {
        KvPool::new(BlockAllocator::new(blocks, 16))
    }

    #[test]
    fn admit_grow_release_cycle() {
        let mut p = pool(8);
        p.admit(1, 30).unwrap(); // 2 blocks
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.resident_tokens(), 30);
        // 31st and 32nd tokens fit in block 2; 33rd allocates block 3.
        p.append_token(1).unwrap();
        p.append_token(1).unwrap();
        assert_eq!(p.used_blocks(), 2);
        p.append_token(1).unwrap();
        assert_eq!(p.used_blocks(), 3);
        assert_eq!(p.release(1).unwrap(), 3);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn admit_fails_cleanly_when_full() {
        let mut p = pool(2);
        p.admit(1, 32).unwrap();
        let err = p.admit(2, 1).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(p.num_seqs(), 1);
        assert!(!p.contains(2));
    }

    #[test]
    fn duplicate_admit_rejected() {
        let mut p = pool(4);
        p.admit(7, 1).unwrap();
        assert_eq!(p.admit(7, 1).unwrap_err(), KvError::DuplicateSeq(7));
    }

    #[test]
    fn preemption_is_lifo() {
        let mut p = pool(6);
        p.admit(1, 16).unwrap();
        p.admit(2, 16).unwrap();
        p.admit(3, 16).unwrap();
        assert_eq!(p.preemption_victim(), Some(3));
        let (victim, tokens) = p.preempt().unwrap();
        assert_eq!((victim, tokens), (3, 16));
        assert_eq!(p.preemption_victim(), Some(2));
        assert!(!p.contains(3));
    }

    #[test]
    fn release_unknown_errors() {
        let mut p = pool(2);
        assert_eq!(p.release(9).unwrap_err(), KvError::UnknownSeq(9));
        assert_eq!(p.append_token(9).unwrap_err(), KvError::UnknownSeq(9));
    }

    #[test]
    fn occupancy_tracks_blocks() {
        let mut p = pool(4);
        assert_eq!(p.occupancy(), 0.0);
        p.admit(1, 32).unwrap();
        assert!((p.occupancy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn can_admit_matches_admit() {
        let mut p = pool(2);
        assert!(p.can_admit(32));
        assert!(!p.can_admit(33));
        p.admit(1, 32).unwrap();
        assert!(!p.can_admit(1));
    }

    #[test]
    fn append_when_full_errors_and_preserves_state() {
        let mut p = pool(1);
        p.admit(1, 16).unwrap();
        let err = p.append_token(1).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(p.seq(1).unwrap().tokens, 16, "failed append must not mutate");
    }

    #[test]
    fn bulk_append_matches_per_token_appends() {
        // Same block growth either way (block identity may differ; counts
        // and token lengths may not).
        for (start, n) in [(1usize, 1usize), (15, 2), (16, 16), (30, 40), (16, 0)] {
            let mut a = pool(64);
            let mut b = pool(64);
            a.admit(1, start).unwrap();
            b.admit(1, start).unwrap();
            for _ in 0..n {
                a.append_token(1).unwrap();
            }
            b.append_tokens(1, n).unwrap();
            assert_eq!(a.seq(1).unwrap().tokens, b.seq(1).unwrap().tokens, "({start},{n})");
            assert_eq!(a.used_blocks(), b.used_blocks(), "({start},{n})");
            assert_eq!(a.free_blocks(), b.free_blocks(), "({start},{n})");
        }
    }

    #[test]
    fn bulk_append_is_atomic_on_failure() {
        let mut p = pool(2);
        p.admit(1, 16).unwrap(); // 1 block, full
        let err = p.append_tokens(1, 17).unwrap_err(); // needs 2 blocks, 1 free
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(p.seq(1).unwrap().tokens, 16, "failed bulk append must not mutate");
        assert_eq!(p.free_blocks(), 1);
        assert_eq!(p.append_tokens(9, 1).unwrap_err(), KvError::UnknownSeq(9));
    }

    #[test]
    fn plan_bulk_steps_prices_the_allocation_schedule() {
        // Two sequences at 16-token blocks: tokens 16 (boundary: allocates
        // at step 1, 17, ...) and 30 (allocates at step 3, 19, ...).
        let mut p = pool(4);
        p.admit(1, 16).unwrap();
        p.admit(2, 30).unwrap();
        assert_eq!(p.free_blocks(), 1);
        let mut allocs = Vec::new();
        // Step 1 takes the last free block; step 2 allocates nothing;
        // step 3 needs a block that is not there.
        let k = p.plan_bulk_steps(10, &mut allocs);
        assert_eq!(k, 2);
        assert_eq!(allocs, vec![1, 0]);
        assert_eq!(p.bulk_horizon(10), 2, "fill-free variant agrees on the horizon");
        // With a bigger pool the plan runs to the horizon.
        let mut p = pool(16);
        p.admit(1, 16).unwrap();
        p.admit(2, 30).unwrap();
        let k = p.plan_bulk_steps(10, &mut allocs);
        assert_eq!(k, 10);
        assert_eq!(allocs.len(), 10);
        assert_eq!(allocs[0], 1, "seq 1 crosses at step 1");
        assert_eq!(allocs[2], 1, "seq 2 crosses at step 3");
        // An empty pool absorbs any horizon with zero allocations.
        let p = pool(4);
        assert_eq!(p.plan_bulk_steps(5, &mut allocs), 5);
        assert_eq!(allocs, vec![0; 5]);
        assert_eq!(p.plan_bulk_steps(0, &mut allocs), 0);
    }

    #[test]
    fn property_plan_bulk_steps_matches_replayed_appends() {
        // The plan must predict exactly what per-token appends do: k is
        // the last whole step that succeeds, step k+1 fails for at least
        // one sequence, and the per-step allocation counts match.
        crate::util::prop::check("kv_plan_bulk_steps", 60, |rng| {
            let blocks = 4 + rng.range_usize(0, 60);
            let bt = 1 + rng.range_usize(0, 31);
            let mut p = KvPool::new(BlockAllocator::new(blocks, bt));
            let n_seq = 1 + rng.range_usize(0, 8);
            for id in 0..n_seq as u64 {
                let tokens = 1 + rng.range_usize(0, 3 * bt);
                if p.admit(id, tokens).is_err() {
                    break;
                }
            }
            if p.num_seqs() == 0 {
                return;
            }
            let max_steps = 1 + rng.range_usize(0, 80);
            let mut allocs = Vec::new();
            let k = p.plan_bulk_steps(max_steps, &mut allocs);
            assert_eq!(allocs.len(), k);
            assert_eq!(p.bulk_horizon(max_steps), k, "fill-free variant agrees");
            // Replay with per-token appends on a clone.
            let mut q = KvPool::new(BlockAllocator::new(blocks, bt));
            let ids: Vec<SeqId> = p.seq_ids().collect();
            for &id in &ids {
                q.admit(id, p.seq(id).unwrap().tokens).unwrap();
            }
            for step in 0..k {
                let before = q.used_blocks();
                for &id in &ids {
                    let ok = q.append_token(id).is_ok();
                    assert!(ok, "planned step {} must succeed", step + 1);
                }
                assert_eq!(
                    (q.used_blocks() - before) as u32,
                    allocs[step],
                    "allocation count at step {}",
                    step + 1
                );
            }
            if k < max_steps {
                // The first unplanned step must fail for some sequence.
                let failed = ids.iter().any(|&id| q.append_token(id).is_err());
                assert!(failed, "step {} should exhaust the pool", k + 1);
            }
        });
    }
}
