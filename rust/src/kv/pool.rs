//! Per-sequence block tables over the [`BlockAllocator`], with preemption.

use std::collections::HashMap;

use super::block::{BlockAllocator, BlockId};

/// Opaque sequence (request) identifier.
pub type SeqId = u64;

/// One sequence's KV state: its block table and logical token length.
#[derive(Debug, Clone)]
pub struct SeqKv {
    pub blocks: Vec<BlockId>,
    pub tokens: usize,
}

impl SeqKv {
    /// Token capacity of the currently-held blocks.
    fn capacity(&self, block_tokens: usize) -> usize {
        self.blocks.len() * block_tokens
    }
}

/// KV pool: sequences → block tables, growth, and preemption.
#[derive(Debug)]
pub struct KvPool {
    alloc: BlockAllocator,
    seqs: HashMap<SeqId, SeqKv>,
    /// Admission order, for vLLM-style last-come-first-preempted victims.
    order: Vec<SeqId>,
}

impl KvPool {
    pub fn new(alloc: BlockAllocator) -> Self {
        KvPool { alloc, seqs: HashMap::new(), order: Vec::new() }
    }

    pub fn block_tokens(&self) -> usize {
        self.alloc.block_tokens()
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn total_blocks(&self) -> usize {
        self.alloc.num_blocks()
    }

    /// Total tokens resident across all sequences.
    pub fn resident_tokens(&self) -> usize {
        self.seqs.values().map(|s| s.tokens).sum()
    }

    /// Pool saturation in [0, 1] (block granularity).
    pub fn occupancy(&self) -> f64 {
        if self.alloc.num_blocks() == 0 {
            return 1.0;
        }
        self.alloc.used_blocks() as f64 / self.alloc.num_blocks() as f64
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq(&self, id: SeqId) -> Option<&SeqKv> {
        self.seqs.get(&id)
    }

    /// Can a new sequence of `tokens` tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.alloc.free_blocks() >= self.alloc.blocks_for(tokens)
    }

    /// Admit a sequence with `tokens` already present (its prefill KV).
    /// Fails (without side effects) when blocks are unavailable.
    pub fn admit(&mut self, id: SeqId, tokens: usize) -> Result<(), KvError> {
        if self.seqs.contains_key(&id) {
            return Err(KvError::DuplicateSeq(id));
        }
        let need = self.alloc.blocks_for(tokens.max(1));
        let blocks = self.alloc.alloc_n(need).ok_or(KvError::OutOfBlocks {
            requested: need,
            available: self.alloc.free_blocks(),
        })?;
        self.seqs.insert(id, SeqKv { blocks, tokens });
        self.order.push(id);
        Ok(())
    }

    /// Append one generated token to a sequence, growing its table by a
    /// block when it crosses a boundary.
    pub fn append_token(&mut self, id: SeqId) -> Result<(), KvError> {
        let block_tokens = self.alloc.block_tokens();
        let seq = self.seqs.get_mut(&id).ok_or(KvError::UnknownSeq(id))?;
        if seq.tokens + 1 > seq.capacity(block_tokens) {
            let blk = self.alloc.alloc().ok_or(KvError::OutOfBlocks {
                requested: 1,
                available: 0,
            })?;
            seq.blocks.push(blk);
        }
        seq.tokens += 1;
        Ok(())
    }

    /// Release a sequence, returning its blocks to the pool.
    pub fn release(&mut self, id: SeqId) -> Result<usize, KvError> {
        let seq = self.seqs.remove(&id).ok_or(KvError::UnknownSeq(id))?;
        let n = seq.blocks.len();
        self.alloc.free_all(seq.blocks);
        self.order.retain(|&s| s != id);
        Ok(n)
    }

    /// Pick the preemption victim: the most recently admitted sequence
    /// (vLLM's recompute-preemption order — newest requests have the least
    /// sunk decode work).
    pub fn preemption_victim(&self) -> Option<SeqId> {
        self.order.last().copied()
    }

    /// Preempt (evict) the victim, freeing its blocks. Returns the evicted
    /// sequence's id and token count so the scheduler can re-queue it for
    /// recompute.
    pub fn preempt(&mut self) -> Option<(SeqId, usize)> {
        let victim = self.preemption_victim()?;
        let tokens = self.seqs[&victim].tokens;
        self.release(victim).expect("victim exists");
        Some((victim, tokens))
    }

    pub fn seq_ids(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.order.iter().copied()
    }
}

/// KV pool errors.
#[derive(Debug, PartialEq, Eq)]
pub enum KvError {
    DuplicateSeq(SeqId),
    UnknownSeq(SeqId),
    OutOfBlocks { requested: usize, available: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::DuplicateSeq(id) => write!(f, "sequence {id} already admitted"),
            KvError::UnknownSeq(id) => write!(f, "sequence {id} not found"),
            KvError::OutOfBlocks { requested, available } => {
                write!(f, "out of KV blocks: requested {requested}, available {available}")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: usize) -> KvPool {
        KvPool::new(BlockAllocator::new(blocks, 16))
    }

    #[test]
    fn admit_grow_release_cycle() {
        let mut p = pool(8);
        p.admit(1, 30).unwrap(); // 2 blocks
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.resident_tokens(), 30);
        // 31st and 32nd tokens fit in block 2; 33rd allocates block 3.
        p.append_token(1).unwrap();
        p.append_token(1).unwrap();
        assert_eq!(p.used_blocks(), 2);
        p.append_token(1).unwrap();
        assert_eq!(p.used_blocks(), 3);
        assert_eq!(p.release(1).unwrap(), 3);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn admit_fails_cleanly_when_full() {
        let mut p = pool(2);
        p.admit(1, 32).unwrap();
        let err = p.admit(2, 1).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(p.num_seqs(), 1);
        assert!(!p.contains(2));
    }

    #[test]
    fn duplicate_admit_rejected() {
        let mut p = pool(4);
        p.admit(7, 1).unwrap();
        assert_eq!(p.admit(7, 1).unwrap_err(), KvError::DuplicateSeq(7));
    }

    #[test]
    fn preemption_is_lifo() {
        let mut p = pool(6);
        p.admit(1, 16).unwrap();
        p.admit(2, 16).unwrap();
        p.admit(3, 16).unwrap();
        assert_eq!(p.preemption_victim(), Some(3));
        let (victim, tokens) = p.preempt().unwrap();
        assert_eq!((victim, tokens), (3, 16));
        assert_eq!(p.preemption_victim(), Some(2));
        assert!(!p.contains(3));
    }

    #[test]
    fn release_unknown_errors() {
        let mut p = pool(2);
        assert_eq!(p.release(9).unwrap_err(), KvError::UnknownSeq(9));
        assert_eq!(p.append_token(9).unwrap_err(), KvError::UnknownSeq(9));
    }

    #[test]
    fn occupancy_tracks_blocks() {
        let mut p = pool(4);
        assert_eq!(p.occupancy(), 0.0);
        p.admit(1, 32).unwrap();
        assert!((p.occupancy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn can_admit_matches_admit() {
        let mut p = pool(2);
        assert!(p.can_admit(32));
        assert!(!p.can_admit(33));
        p.admit(1, 32).unwrap();
        assert!(!p.can_admit(1));
    }

    #[test]
    fn append_when_full_errors_and_preserves_state() {
        let mut p = pool(1);
        p.admit(1, 16).unwrap();
        let err = p.append_token(1).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(p.seq(1).unwrap().tokens, 16, "failed append must not mutate");
    }
}
